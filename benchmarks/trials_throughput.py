"""Beyond-paper: device-sharded IID-trial throughput (the pod axis), plus
the composed pod x grid mesh (DESIGN.md §6).

The paper runs IID trials serially ("for L=100 we executed 2000 times" —
Park et al.; the dissertation's Table 4.2 runs 20). The trial subsystem
(``repro.core.trials``) batches trials through vmap AND shards the trial
axis across every local device, which is the biggest statistics-throughput
lever on accelerators. Measure aggregate updates/s per trial count and per
pod width (device count) via the chunked driver — results are bit-identical
for every width, so the sweep is a pure throughput comparison.

The second sweep drives the ``sharded_pod`` engine: the same trial batch on
composed ``(pod, rows, cols)`` mesh factorizations, where each trial's
lattice is additionally domain-decomposed with halo exchange. On CPU fake
devices this measures layout overhead, not speedup — the point is that
every factorization computes the identical trajectories, so the choice is
purely a throughput/memory trade (grid-shard only when a lattice outgrows
one device; see DESIGN.md §6).

Run under fake devices to see both axes on CPU:
  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
      PYTHONPATH=src python -m benchmarks.trials_throughput
"""
from __future__ import annotations

import jax

from repro.core.scenarios import EngineConfig, RunConfig, make_scenario
from repro.core.trials import run_trials

from .common import emit, note, smoke, time_fn

L, MCS = smoke(16, 48), smoke(4, 10)


def _device_counts() -> tuple:
    n = jax.local_device_count()
    counts = {1, n}
    if n >= 2:
        counts.add(2)
    return tuple(sorted(counts))


def _mesh_shapes(L: int, tile) -> tuple:
    """Composed (pod, rows, cols) factorizations of the local devices that
    this lattice admits (device blocks must be unions of tiles)."""
    n = jax.local_device_count()
    th, tw = tile
    shapes = []
    for rows in (1, 2, 4):
        for cols in (1, 2, 4):
            pod = n // (rows * cols)
            if pod < 1 or rows * cols > n:
                continue
            if L % rows or (L // rows) % th or L % cols or (L // cols) % tw:
                continue
            shapes.append((pod, rows, cols))
    return tuple(shapes)


def run() -> None:
    note(f"device-sharded IID trials, L={L}, {MCS} MCS each (beyond-paper); "
         f"{jax.local_device_count()} local device(s)")
    # nspecies5's C(5,{1,2}) circulant IS the classic RPSLS network;
    # observables pinned off — this sweep measures pure dynamics throughput
    sc = make_scenario("nspecies5", mobility=1e-4)
    rc = RunConfig(length=L, height=L, mcs=MCS, chunk_mcs=MCS, seed=0,
                   observables=())

    for n in smoke((4,), (4, 16)):
        for d in _device_counts():
            f = lambda: run_trials(  # noqa: E731
                sc, None, n, trial_devices=d, stop_on_stasis=False,
                engine=EngineConfig(engine="batched"), run=rc)
            t = time_fn(f, warmup=1, iters=2)
            emit(f"trials_pod_n{n}_d{d}", t,
                 f"{n * MCS * L * L / t / 1e6:.2f} Mupd/s aggregate "
                 f"across {d} device(s)")

    # composed pod x grid mesh: same trials, every admissible factorization
    tile = (8, 8) if L % 16 else (8, 16)
    n = smoke(4, 8)
    for ms in _mesh_shapes(L, tile):
        f = lambda: run_trials(  # noqa: E731
            sc, None, n, stop_on_stasis=False,
            engine=EngineConfig(engine="sharded_pod", tile=tile,
                                mesh_shape=ms), run=rc)
        t = time_fn(f, warmup=1, iters=2)
        emit(f"trials_composed_n{n}_m{ms[0]}x{ms[1]}x{ms[2]}", t,
             f"{n * MCS * L * L / t / 1e6:.2f} Mupd/s aggregate on "
             f"(pod,rows,cols)={ms}")


if __name__ == "__main__":
    run()
