"""Beyond-paper: vmapped IID-trial throughput.

The paper runs IID trials serially ("for L=100 we executed 2000 times" —
Park et al.; the dissertation's Table 4.2 runs 20). Batching trials through
vmap is the biggest statistics-throughput lever on accelerators and is what
the 'pod' mesh axis carries at multi-pod scale. Measure updates/s at
1 / 4 / 16 vmapped trials."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import EscgParams, dominance as dm
from repro.core.lattice import init_grid
from repro.core.simulation import build_mcs_fn

from .common import emit, note, time_fn

L, MCS = 48, 10


def run() -> None:
    note(f"vmapped IID trials, L={L}, {MCS} MCS each (beyond-paper)")
    p = EscgParams(length=L, height=L, species=5, mobility=1e-4,
                   engine="batched", seed=0)
    dom = jnp.asarray(dm.RPSLS())
    one = build_mcs_fn(p, dom)

    def trial(grid, key):
        def body(c, _):
            g, k = c
            k, k1 = jax.random.split(k)
            g, _, _ = one(g, k1)
            return (g, k), None
        (g, _), _ = jax.lax.scan(body, (grid, key), length=MCS)
        return g

    for n in (1, 4, 16):
        keys = jax.random.split(jax.random.PRNGKey(0), n)
        grids = jax.vmap(lambda k: init_grid(k, L, L, 5, 0.1))(keys)
        f = jax.jit(jax.vmap(trial))
        t = time_fn(f, grids, keys, warmup=1, iters=2)
        emit(f"trials_vmap_{n}", t,
             f"{n * MCS * L * L / t / 1e6:.2f} Mupd/s aggregate")


if __name__ == "__main__":
    run()
