"""Beyond-paper: device-sharded IID-trial throughput (the pod axis).

The paper runs IID trials serially ("for L=100 we executed 2000 times" —
Park et al.; the dissertation's Table 4.2 runs 20). The trial subsystem
(``repro.core.trials``) batches trials through vmap AND shards the trial
axis across every local device, which is the biggest statistics-throughput
lever on accelerators. Measure aggregate updates/s per trial count and per
pod width (device count) via the chunked driver — results are bit-identical
for every width, so the sweep is a pure throughput comparison.

Run under fake devices to see the pod axis on CPU:
  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
      PYTHONPATH=src python -m benchmarks.trials_throughput
"""
from __future__ import annotations

import jax

from repro.core import EscgParams, dominance as dm
from repro.core.trials import run_trials

from .common import emit, note, time_fn

L, MCS = 48, 10


def _device_counts() -> tuple:
    n = jax.local_device_count()
    counts = {1, n}
    if n >= 2:
        counts.add(2)
    return tuple(sorted(counts))


def run() -> None:
    note(f"device-sharded IID trials, L={L}, {MCS} MCS each (beyond-paper); "
         f"{jax.local_device_count()} local device(s)")
    p = EscgParams(length=L, height=L, species=5, mobility=1e-4,
                   engine="batched", seed=0)
    dom = dm.RPSLS()

    for n in (4, 16):
        for d in _device_counts():
            f = lambda: run_trials(  # noqa: E731
                p, dom, n, n_mcs=MCS, trial_devices=d, chunk_mcs=MCS,
                stop_on_stasis=False)
            t = time_fn(f, warmup=1, iters=2)
            emit(f"trials_pod_n{n}_d{d}", t,
                 f"{n * MCS * L * L / t / 1e6:.2f} Mupd/s aggregate "
                 f"across {d} device(s)")


if __name__ == "__main__":
    run()
