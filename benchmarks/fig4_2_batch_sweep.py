"""Paper Fig 4.2 — execution time vs the --numRandoms batching parameter.

Paper: total time of 100k-MCS maxStep runs vs numRandoms for L=100/200/400,
with a sweet spot near 5e7. Here: total time of a fixed-MCS batched-engine
run as a function of the arbitration sub-batch size (the engine-level
analogue of numRandoms: randoms consumed per scatter-arbitration window),
L in {32, 64}. Too-small windows pay per-window overhead; too-large windows
waste draws on conflicts — the same U-shape at reduced scale.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import dominance as dm
from repro.core.lattice import init_grid
from repro.core.rng import proposal_batch
from repro.core import batched

from .common import emit, note, smoke, time_fn

MCS = smoke(3, 30)


def run_one(L: int, n_sub: int) -> float:
    n = L * L
    b_sub = max(1, n // n_sub)
    dom = jnp.asarray(dm.RPS())
    te, tem = 0.2, 0.6

    @jax.jit
    def chunk(grid, key):
        def mcs_body(carry, k):
            g, kept = carry
            def body(c, kk):
                g2, kept2 = c
                batch = proposal_batch(kk, b_sub, n, 4)
                g2, k2 = batched.run_proposals(g2, batch, te, tem, dom)
                return (g2, kept2 + k2), None
            (g, kept), _ = jax.lax.scan(
                body, (g, kept), jax.random.split(k, n_sub))
            return (g, kept), None
        (grid, kept), _ = jax.lax.scan(
            mcs_body, (grid, jnp.int32(0)), jax.random.split(key, MCS))
        return grid, kept

    grid = init_grid(jax.random.PRNGKey(0), L, L, 3, 0.1)
    t = time_fn(chunk, grid, jax.random.PRNGKey(1), warmup=1, iters=2)
    return t


def run() -> None:
    note(f"batched-engine window sweep, {MCS} MCS (paper Fig 4.2)")
    for L in smoke((32,), (32, 64)):
        for n_sub in smoke((1, 4), (1, 2, 4, 8, 16, 32)):
            t = run_one(L, n_sub)
            window = L * L // n_sub
            emit(f"batch_sweep_L{L}_window{window}", t,
                 f"{MCS * L * L / t / 1e6:.2f} Mupd/s")


if __name__ == "__main__":
    run()
