"""Paper Figs 3.2/3.3/3.7/3.8 — Zhong et al. ablated-RPSLS density
dynamics: the Paper species must go extinct early (200-600 MCS at L=200;
earlier at reduced L), leaving the Rock-Lizard-Spock / Scissors-Lizard-
Spock sub-cycles. Run per engine to show cross-engine stochastic validity
(paper §4.1)."""
from __future__ import annotations

import time

from repro.core import EscgParams, dominance as dm, metrics, simulate

from .common import emit, note

L, MCS = 64, 1200


def run() -> None:
    note(f"Zhong ablated RPSLS at L={L}, {MCS} MCS (paper Fig 3.2)")
    for engine in ("batched", "sublattice"):
        p = EscgParams(length=L, height=L, species=5, mobility=1e-4,
                       mcs=MCS, chunk_mcs=300, engine=engine, tile=(8, 16),
                       seed=11)
        t0 = time.perf_counter()
        res = simulate(p, dm.zhong_ablated_rpsls(), stop_on_stasis=False)
        dt = time.perf_counter() - t0
        ext = metrics.first_extinction_mcs(res.densities, dm.PAPER)
        alive = int((res.densities[-1][1:] > 0).sum())
        emit(f"zhong_{engine}", dt,
             f"paper_extinct_mcs {ext}; alive_end {alive}; "
             f"rock_end {res.densities[-1][dm.ROCK]:.3f}")


if __name__ == "__main__":
    run()
