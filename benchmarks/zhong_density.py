"""Paper Figs 3.2/3.3/3.7/3.8 — Zhong et al. ablated-RPSLS density
dynamics: the Paper species must go extinct early (200-600 MCS at L=200;
earlier at reduced L), leaving the Rock-Lizard-Spock / Scissors-Lizard-
Spock sub-cycles. Run per engine to show cross-engine stochastic validity
(paper §4.1).

Since the scenario layer (DESIGN.md §10) this is a thin scenario
invocation: the physics (ablated-RPSLS dominance, mobility, S=5) come from
the registered ``zhong_density`` preset; the module only picks engines and
run control. Runs through the chunked trial driver (``repro.core.trials``):
a small IID batch per engine, extinction MCS streamed per chunk instead of
a full density history — the per-trial ``extinction_mcs`` statistic is
exactly the paper's observable."""
from __future__ import annotations

import time

import numpy as np

from repro.core import dominance as dm
from repro.core.scenarios import EngineConfig, RunConfig, make_scenario
from repro.core.trials import run_trials

from .common import emit, note, smoke

L, MCS, TRIALS = smoke(32, 64), smoke(200, 1200), smoke(2, 3)


def run() -> None:
    note(f"Zhong ablated RPSLS at L={L}, {MCS} MCS, {TRIALS} IID trials "
         "(paper Fig 3.2)")
    sc = make_scenario("zhong_density")
    for engine in ("batched", "sublattice"):
        t0 = time.perf_counter()
        res = run_trials(
            sc, None, TRIALS, stop_on_stasis=False,
            engine=EngineConfig(engine=engine, tile=(8, 16)),
            run=RunConfig(length=L, height=L, mcs=MCS,
                          chunk_mcs=300, seed=11))
        dt = time.perf_counter() - t0
        ext = res.extinction_mcs[:, dm.PAPER - 1]       # per-trial, exact MCS
        ext_str = ("/".join(str(int(e)) for e in ext))
        alive = res.survival.sum(axis=1)
        emit(f"zhong_{engine}", dt,
             f"paper_extinct_mcs {ext_str}; "
             f"alive_end {alive.min()}-{alive.max()}; "
             f"rock_end {np.mean(res.densities[:, dm.ROCK]):.3f}")


if __name__ == "__main__":
    run()
