"""Paper Fig 4.3 / Table 4.1 — execution time vs lattice size per engine.

Paper: single-threaded C++ vs Metal vs CUDA (+maxStep variants), L=100..3200
to 100k MCS; CUDA-maxStep up to 28.4x over single-threaded at L=800. Here:
the E1 sequential oracle (single-threaded baseline), E2 batched (maxStep
port) and E3 sublattice (TPU-native) engines on CPU at reduced MCS —
the SPEEDUP STRUCTURE (parallel engines pulling away with L) is the claim
under test; absolute times are CPU-bound.

The ``sharded`` engine extends the sweep past single-device memory: set
``ESCG_FAKE_DEVICES=N`` (fake CPU devices) or run on a real multi-chip
backend, and the largest lattices (the paper's L=3200 point) run
domain-decomposed with halo exchange, bit-identical to the single-device
sublattice trajectory.
"""
from __future__ import annotations

import os

# must happen before the first jax import anywhere in the process
if os.environ.get("ESCG_FAKE_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count="
        + os.environ["ESCG_FAKE_DEVICES"])

import jax

from repro.core import EscgParams, dominance as dm, engines

from .common import emit, note, smoke, time_fn

MCS = smoke(2, 20)

ENGINES_SWEPT = ("reference", "batched", "sublattice")


def _params(engine: str, L: int, **overrides) -> EscgParams:
    tile = (8, 16) if L >= 16 else (4, 8)
    return EscgParams(length=L, height=L, species=3, mobility=1e-4, mcs=MCS,
                      chunk_mcs=MCS, engine=engine, tile=tile, seed=0,
                      empty=0.1, **overrides)


def run_engine(engine: str, L: int, **overrides) -> float:
    p = _params(engine, L, **overrides)
    # measure a jitted chunk directly (excludes trace/compile, like the
    # paper excludes process startup)
    from repro.core.simulation import build_chunk_fn
    import jax.numpy as jnp
    from repro.core.lattice import init_grid
    dom = jnp.asarray(dm.RPS())
    eng = engines.build(p, dom)
    chunk = build_chunk_fn(p, dom, one_mcs=eng.one_mcs)
    grid = init_grid(jax.random.PRNGKey(0), L, L, 3, 0.1)
    if eng.grid_sharding is not None:
        grid = jax.device_put(grid, eng.grid_sharding)
    key = jax.random.PRNGKey(1)
    return time_fn(lambda: chunk(grid, key, MCS), warmup=1, iters=2)


def run() -> None:
    note(f"engine scaling, {MCS} MCS per point (paper Fig 4.3/Table 4.1)")
    n_dev = len(jax.devices())
    sizes = smoke((32,), (32, 64, 128, 256))
    swept = ENGINES_SWEPT + (("sharded",) if n_dev > 1 else ())
    if n_dev > 1:
        note(f"sharded engine over {n_dev} devices "
             f"(ESCG_FAKE_DEVICES={os.environ.get('ESCG_FAKE_DEVICES', '')})")
        sizes = sizes + smoke((), (512,))  # past-single-device sweep point
    base = {}
    for L in sizes:
        for engine in swept:
            if engine == "reference" and L > 128:
                continue               # the paper's baseline also tops out
            if engine != "sharded" and L > 256:
                continue               # largest size: sharded only
            t = run_engine(engine, L)
            upd = MCS * L * L / t
            base[(engine, L)] = t
            speedup = (base[("reference", L)] / t
                       if ("reference", L) in base else float("nan"))
            emit(f"scaling_{engine}_L{L}", t,
                 f"{upd / 1e6:.2f} Mupd/s; vs_seq {speedup:.1f}x")
    if n_dev > 1:
        # local_kernel='pallas': the sharded engine's shard_map region runs
        # the VMEM-tiled kernel path (bit-identical to jnp; on CPU the
        # Pallas interpreter dominates, so keep it to the smallest size —
        # the TPU number is the structural claim, DESIGN.md §6)
        L = sizes[0]
        t = run_engine("sharded", L, local_kernel="pallas")
        emit(f"scaling_sharded_pallas_L{L}", t,
             f"{MCS * L * L / t / 1e6:.2f} Mupd/s; local_kernel=pallas "
             f"vs jnp {base[('sharded', L)] / t:.2f}x")


if __name__ == "__main__":
    run()
