"""Paper Fig 4.3 / Table 4.1 — execution time vs lattice size per engine.

Paper: single-threaded C++ vs Metal vs CUDA (+maxStep variants), L=100..3200
to 100k MCS; CUDA-maxStep up to 28.4x over single-threaded at L=800. Here:
the E1 sequential oracle (single-threaded baseline), E2 batched (maxStep
port) and E3 sublattice (TPU-native) engines on CPU at reduced MCS —
the SPEEDUP STRUCTURE (parallel engines pulling away with L) is the claim
under test; absolute times are CPU-bound.
"""
from __future__ import annotations

import jax

from repro.core import EscgParams, dominance as dm, simulate

from .common import emit, note, time_fn

MCS = 20


def run_engine(engine: str, L: int) -> float:
    tile = (8, 16) if L >= 16 else (4, 8)
    p = EscgParams(length=L, height=L, species=3, mobility=1e-4, mcs=MCS,
                   chunk_mcs=MCS, engine=engine, tile=tile, seed=0,
                   empty=0.1)
    # measure a jitted chunk directly (excludes trace/compile, like the
    # paper excludes process startup)
    from repro.core.simulation import build_chunk_fn
    import jax.numpy as jnp
    from repro.core.lattice import init_grid
    dom = jnp.asarray(dm.RPS())
    chunk = build_chunk_fn(p, dom)
    grid = init_grid(jax.random.PRNGKey(0), L, L, 3, 0.1)
    key = jax.random.PRNGKey(1)
    return time_fn(lambda: chunk(grid, key, MCS), warmup=1, iters=2)


def run() -> None:
    note(f"engine scaling, {MCS} MCS per point (paper Fig 4.3/Table 4.1)")
    base = {}
    for L in (32, 64, 128, 256):
        for engine in ("reference", "batched", "sublattice"):
            if engine == "reference" and L > 128:
                continue               # the paper's baseline also tops out
            t = run_engine(engine, L)
            upd = MCS * L * L / t
            base[(engine, L)] = t
            speedup = (base[("reference", L)] / t
                       if ("reference", L) in base else float("nan"))
            emit(f"scaling_{engine}_L{L}", t,
                 f"{upd / 1e6:.2f} Mupd/s; vs_seq {speedup:.1f}x")


if __name__ == "__main__":
    run()
