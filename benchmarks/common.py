"""Shared benchmark harness utilities (DESIGN.md §7). Every benchmark
prints ``name,us_per_call,derived`` CSV rows (brief requirement) plus a
human summary to stderr; set ``BENCH_JSON=1`` to emit one JSON object per
row instead (the format documented in benchmarks/README.md).

Set ``ESCG_BENCH_SMOKE=1`` to shrink every sweep to a tiny CI-sized
configuration (``smoke()`` below) — tests/test_benchmarks.py runs each
module this way so benchmark code can never silently rot."""
from __future__ import annotations

import json
import os
import sys
import time
from typing import Callable, Tuple

import jax

SMOKE = os.environ.get("ESCG_BENCH_SMOKE", "").lower() not in (
    "", "0", "false", "no")


def smoke(small, full):
    """Pick the tiny smoke-test value under ESCG_BENCH_SMOKE, else the
    real sweep value."""
    return small if SMOKE else full


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3,
            **kw) -> float:
    """Median wall time of fn(*args) in seconds (block_until_ready)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def emit(name: str, seconds: float, derived: str = "") -> None:
    if os.environ.get("BENCH_JSON"):
        print(json.dumps({"name": name,
                          "us_per_call": round(seconds * 1e6, 1),
                          "derived": derived}), flush=True)
    else:
        print(f"{name},{seconds * 1e6:.1f},{derived}", flush=True)


def note(msg: str) -> None:
    print(f"# {msg}", file=sys.stderr, flush=True)
