"""Shared benchmark harness utilities (DESIGN.md §7). Every benchmark
prints ``name,us_per_call,derived`` CSV rows (brief requirement) plus a
human summary to stderr; set ``BENCH_JSON=1`` to emit one JSON object per
row instead (the format documented in benchmarks/README.md).

Set ``ESCG_BENCH_SMOKE=1`` to shrink every sweep to a tiny CI-sized
configuration (``smoke()`` below) — tests/test_benchmarks.py runs each
module this way so benchmark code can never silently rot."""
from __future__ import annotations

import json
import os
import sys
import time
from typing import Callable, Tuple

import jax

SMOKE = os.environ.get("ESCG_BENCH_SMOKE", "").lower() not in (
    "", "0", "false", "no")


def smoke(small, full):
    """Pick the tiny smoke-test value under ESCG_BENCH_SMOKE, else the
    real sweep value."""
    return small if SMOKE else full


def median(xs) -> float:
    """True median: mean of the two middle elements for even-length
    samples. The old ``sorted[n // 2]`` shortcut silently returned the
    MAX of a 2-sample run (the exact shape bench_gate uses), biasing
    every gated number pessimistic by the full run-to-run jitter."""
    if not xs:
        raise ValueError("median of empty sample")
    s = sorted(xs)
    n = len(s)
    if n % 2:
        return s[n // 2]
    return (s[n // 2 - 1] + s[n // 2]) / 2.0


def time_stats(fn: Callable, *args, warmup: int = 1, iters: int = 3,
               **kw) -> dict:
    """Per-call timing stats of fn(*args) in MICROseconds
    (block_until_ready): ``{"median_us", "mean_us", "min_us", "max_us",
    "n"}`` — the v3 bench-gate row payload."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        times.append(time.perf_counter() - t0)
    return {
        "median_us": round(median(times) * 1e6, 1),
        "mean_us": round(sum(times) / len(times) * 1e6, 1),
        "min_us": round(min(times) * 1e6, 1),
        "max_us": round(max(times) * 1e6, 1),
        "n": len(times),
    }


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3,
            **kw) -> float:
    """Median wall time of fn(*args) in seconds (block_until_ready)."""
    stats = time_stats(fn, *args, warmup=warmup, iters=iters, **kw)
    return stats["median_us"] / 1e6


def emit(name: str, seconds: float, derived: str = "") -> None:
    if os.environ.get("BENCH_JSON"):
        print(json.dumps({"name": name,
                          "us_per_call": round(seconds * 1e6, 1),
                          "derived": derived}), flush=True)
    else:
        print(f"{name},{seconds * 1e6:.1f},{derived}", flush=True)


def note(msg: str) -> None:
    print(f"# {msg}", file=sys.stderr, flush=True)
