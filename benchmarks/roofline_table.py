"""§Roofline table — reads the dry-run JSONs (launch/dryrun.py) and prints
the three roofline terms per (arch x shape x mesh) with the dominant
bottleneck. Recomputes MODEL_FLOPS/useful ratios from the live configs (so
fixes to active-param accounting don't require recompiling the sweep)."""
from __future__ import annotations

import glob
import json
import os

from repro.configs import ARCHS, SHAPES
from repro.models.registry import build_model
from repro.parallel import roofline

from .common import emit, note

DRYRUN_DIR = os.environ.get("DRYRUN_DIR", "experiments/dryrun")


def load_records():
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def active_params(arch: str) -> int:
    if arch in ARCHS:
        return build_model(ARCHS[arch]).n_active_params()
    return 0


def run() -> None:
    recs = load_records()
    if not recs:
        note("no dry-run records found — run "
             "PYTHONPATH=src python -m repro.launch.dryrun first")
        return
    note(f"{len(recs)} dry-run records from {DRYRUN_DIR}")
    header = (f"{'arch':<18s} {'shape':<12s} {'mesh':<10s} "
              f"{'compute_s':>10s} {'memory_s':>10s} {'coll_s':>8s} "
              f"{'dominant':>10s} {'useful':>7s} {'GiB/dev':>8s}")
    note(header)
    for r in recs:
        if r.get("status") == "skipped":
            note(f"{r['arch']:<18s} {r['shape']:<12s} {r['mesh']:<10s} "
                 f"SKIPPED: {r['reason'][:60]}")
            continue
        if r.get("status") != "ok":
            note(f"{r['arch']:<18s} {r['shape']:<12s} {r['mesh']:<10s} "
                 f"ERROR: {r.get('error', '?')[:60]}")
            continue
        t = r["roofline"]
        na = active_params(r["arch"])
        if na and r.get("n_tokens"):
            kind = "train" if r["shape"] == "train_4k" else "serve"
            mf = roofline.model_flops(na, r["n_tokens"], kind)
            useful = (mf / r["chips"]) / t["flops_per_chip"] \
                if t["flops_per_chip"] else 0.0
        else:
            useful = t.get("useful_flops_ratio", 0.0)
        mem = r.get("memory", {}).get("total_bytes_per_device", 0) / 2**30
        note(f"{r['arch']:<18s} {r['shape']:<12s} {r['mesh']:<10s} "
             f"{t['compute_s']:>10.4f} {t['memory_s']:>10.4f} "
             f"{t['collective_s']:>8.4f} {t['dominant']:>10s} "
             f"{useful:>7.3f} {mem:>8.2f}")
        emit(f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}",
             t["bound_s"],
             f"dom {t['dominant']}; useful {useful:.3f}; mem {mem:.2f}GiB")


if __name__ == "__main__":
    run()
