"""Paper Fig 4.4 — per-trial runtime variance, single-MCS vs multi-MCS
(maxStep) launch granularity.

Paper: Metal shows warm-up spikes (PSO compilation) in single-MCS mode;
CUDA is stable. Here: one-MCS-per-dispatch vs a whole chunk per dispatch,
including the first (compile) call — XLA shows the same warm-up-then-stable
structure; chunked dispatch amortizes it away.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import EscgParams, dominance as dm
from repro.core.lattice import init_grid
from repro.core.simulation import build_chunk_fn

from .common import emit, note, smoke

L, TRIALS, CHUNK = smoke(16, 64), smoke(3, 10), smoke(5, 20)


def run() -> None:
    note("per-trial variance incl. warm-up (paper Fig 4.4)")
    p = EscgParams(length=L, height=L, species=3, mobility=1e-4,
                   engine="batched", seed=0)
    dom = jnp.asarray(dm.RPS())
    chunk = build_chunk_fn(p, dom)
    grid = init_grid(jax.random.PRNGKey(0), L, L, 3, 0.1)

    for mode, n_mcs, reps in (("single_mcs", 1, CHUNK),
                              ("max_step", CHUNK, 1)):
        times = []
        for trial in range(TRIALS):
            key = jax.random.PRNGKey(trial)
            t0 = time.perf_counter()
            g = grid
            for _ in range(reps):
                g, key, cnts, _, _ = chunk(g, key, n_mcs)
            jax.block_until_ready(g)
            times.append(time.perf_counter() - t0)
        arr = np.array(times)
        emit(f"variance_{mode}_mean", float(arr.mean()),
             f"std {arr.std():.4f}s first {arr[0]:.3f}s "
             f"rest_mean {arr[1:].mean():.3f}s")


if __name__ == "__main__":
    run()
