"""Benchmark harness — one module per paper table/figure (DESIGN.md §7).

Prints ``name,us_per_call,derived`` CSV rows. Set BENCH_FAST=0 for the full
(slower) settings.
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    from . import (fig4_1_prng, fig4_2_batch_sweep, fig4_3_scaling,
                   fig4_4_variance, fig4_9_park_heatmap, roofline_table,
                   table4_2_park_stats, trials_throughput, zhong_density)
    t0 = time.time()
    print("name,us_per_call,derived")
    for mod in (fig4_1_prng, fig4_2_batch_sweep, fig4_3_scaling,
                fig4_4_variance, zhong_density, fig4_9_park_heatmap,
                table4_2_park_stats, trials_throughput, roofline_table):
        print(f"# ===== {mod.__name__} =====", file=sys.stderr, flush=True)
        try:
            mod.run()
        except Exception as e:                          # noqa: BLE001
            print(f"{mod.__name__},ERROR,{e}", flush=True)
    print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
