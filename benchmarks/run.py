"""Benchmark harness — one module per paper table/figure (DESIGN.md §7).

Prints ``name,us_per_call,derived`` CSV rows. Set BENCH_FAST=0 for the full
(slower) settings.
"""
from __future__ import annotations

import os
import sys
import time
import traceback


def main() -> None:
    # bench_gate is intentionally absent: it is the perf GATE, not a
    # figure — the CI perf-smoke job runs it standalone (with --out) and
    # would otherwise pay its engine-build sweep twice per run
    from . import (fig4_1_prng, fig4_2_batch_sweep, fig4_3_scaling,
                   fig4_4_variance, fig4_9_park_heatmap, roofline_table,
                   table4_2_park_stats, trials_throughput, zhong_density)
    t0 = time.time()
    if not os.environ.get("BENCH_JSON"):
        print("name,us_per_call,derived")   # CSV header; JSON rows need none
    failures = []
    for mod in (fig4_1_prng, fig4_2_batch_sweep, fig4_3_scaling,
                fig4_4_variance, zhong_density, fig4_9_park_heatmap,
                table4_2_park_stats, trials_throughput, roofline_table):
        print(f"# ===== {mod.__name__} =====", file=sys.stderr, flush=True)
        try:
            mod.run()
        except Exception as e:                          # noqa: BLE001
            # full traceback to stderr; keep stdout well-formed (a bare
            # ERROR line would corrupt a BENCH_JSON=1 row stream) and fail
            # the process so CI blames the right step
            failures.append(mod.__name__)
            traceback.print_exc(file=sys.stderr)
            if not os.environ.get("BENCH_JSON"):
                print(f"{mod.__name__},ERROR,{e}", flush=True)
    print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)
    if failures:
        raise SystemExit(f"benchmark module(s) failed: {', '.join(failures)}")


if __name__ == "__main__":
    main()
