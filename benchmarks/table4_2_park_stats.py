"""Paper Table 4.2 — std of species-5 extinction probability across system
sizes and MCS horizons (the dissertation's multimodality audit of Park et
al.). Reduced: L in {16, 24}, MCS in {0, 200, 600}, 6 IID trials.

Every (L, MCS) cell is one invocation of the registered ``probabilistic``
scenario (the Park alliance physics live in ``core/scenarios.py``,
DESIGN.md §10) through the chunked, device-sharded trial driver
(``repro.core.trials`` via ``park.species5_extinction_std``): the Park
protocol — 2000 serial runs in the original — executes in device-parallel
chunks with streamed per-chunk statistics and per-trial stasis
early-exit."""
from __future__ import annotations

import time

from repro.core.park import species5_extinction_std

from .common import emit, note, smoke

LS = smoke((16,), (16, 24))
MCS = smoke((0, 100), (0, 200, 600))


def run() -> None:
    note("species-5 extinction std over (L, MCS) (paper Table 4.2), "
         "chunked trial driver")
    t0 = time.perf_counter()
    table = species5_extinction_std(LS, MCS, alpha=0.15, beta=0.75,
                                    gamma=1.0, n_trials=smoke(3, 6))
    dt = time.perf_counter() - t0
    for i, m in enumerate(MCS):
        row = " ".join(f"L{l}:{table[i, j]:.3f}" for j, l in enumerate(LS))
        emit(f"park_std_mcs{m}", dt / len(MCS), row)


if __name__ == "__main__":
    run()
