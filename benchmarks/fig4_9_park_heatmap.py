"""Paper Figs 4.9/4.10 — Park et al. survival-count probabilities over the
(alpha, beta) plane, gamma = 1 (reduced resolution/trials for CPU).

Paper protocol: L=100, terminate after L^2 MCS, many IID runs. Here a
coarse grid at L=32 with vmapped trials; emits the survivors histogram per
(alpha, beta) cell. Each cell is one invocation of the registered
``probabilistic`` scenario (``core/scenarios.py``, DESIGN.md §10) with its
(alpha, beta, gamma) rate knobs. benchmarks/run.py keeps this to a 3x3
grid; examples/park_alliances.py exposes the full sweep.
"""
from __future__ import annotations

import numpy as np

from repro.core.park import survival_probabilities

from .common import emit, note, smoke, time_fn

GRID = smoke((0.5,), (0.1, 0.5, 0.9))
L = smoke(16, 32)
TRIALS = smoke(2, 8)


def run() -> None:
    note(f"Park (alpha,beta) sweep at L={L}, {TRIALS} vmapped IID trials "
         f"per cell, {L*L} MCS (paper Figs 4.9/4.10)")
    import time
    for alpha in GRID:
        for beta in GRID:
            t0 = time.perf_counter()
            ps, hist = survival_probabilities(
                alpha, beta, 1.0, L=L, n_trials=TRIALS, mcs=L * L)
            dt = time.perf_counter() - t0
            mode = int(np.argmax(hist))
            emit(f"park_a{alpha}_b{beta}", dt,
                 f"mode_survivors {mode}; hist "
                 + "|".join(f"{v:.2f}" for v in hist))


if __name__ == "__main__":
    run()
