"""Perf gate — local-kernel x engine-family sweep with a schema-checked
JSON artifact (DESIGN.md §7).

The paper's headline result (Fig 4.2 / §3.2.1) is that eliminating the
materialized random-number buffer is the step that turns the update loop
bandwidth-bound: our ``fused`` local kernel is exactly that move, now
available inside the sharded engines' shard_map regions. This module is
the CI-tracked evidence: it sweeps every local kernel {jnp, pallas,
fused} across every engine family {sublattice, sharded, sharded_pod} and
writes ``BENCH_kernels.json`` — the artifact the ``perf-smoke`` CI job
validates and uploads every run, seeding the perf trajectory.

Stdout keeps the common benchmark contract (``name,us_per_call,derived``
CSV rows, or one JSON object per row under ``BENCH_JSON=1``); the richer
per-row fields land in the artifact. Both formats are validated by the
functions below (also exposed as ``--validate FILE...`` for CI):

* a *row* must carry ``name`` (non-empty str), ``us_per_call`` (number
  > 0) and ``derived`` (str);
* the *document* must carry ``schema == "escg-bench-kernels/v5"``,
  ``backend``/``devices``/``smoke`` metadata and a non-empty ``rows``
  list whose entries extend the row schema with ``family``,
  ``scenario`` (the registered scenario-layer preset the cell ran,
  DESIGN.md §10), ``local_kernel``, ``engine``, ``backend`` (new in v3
  — rows are self-identifying so history lines compare across
  runners), ``observables`` (bool, new in v4 — whether the chunk ran
  the on-device observable pipeline of DESIGN.md §11), ``lattice``
  ([H, W]), ``mcs``, ``n_trials`` (the REQUESTED trial count; 0 for
  the single-lattice families), ``n_pad`` (the padded batch that
  actually ran — v2 conflated the two as ``trials`` and normalized
  throughput over padding), ``updates_per_s`` (normalized over
  *useful* updates: ``mcs * n_cells * max(n_trials, 1)``, never the
  padded batch) and ``timing`` (per-call stats: ``median_us`` /
  ``mean_us`` / ``min_us`` / ``max_us`` / ``n``) — and whose rows must
  cover ALL three local kernels AND all three swept scenarios {park3,
  zhong_density, nspecies5} (the acceptance criterion; a sweep that
  silently drops one fails validation, not review).

New in v5: the document additionally carries one family-``serve``
derived row — the serving layer (DESIGN.md §12) replays the committed
smoke trace (``examples/traces/smoke.jsonl``) through an in-process
``ScenarioServer`` and records requests/s, useful-update throughput
and the compiled-engine cache counters (``validate_serve_row``; the
row rides the same ``--history`` trajectory as the kernel rows, and a
v5 document without one fails validation).

The v4 sweep records *observable overhead* as paired rows: every
engine family runs park3/jnp twice, once with the observable pipeline
off (``observables: false``) and once streaming the park3 observable
set into the device ring buffer (``observables: true``, name suffix
``_obs``); the on-row's ``derived`` string carries the measured
overhead versus its off twin. ISSUE 9's acceptance criterion is that
this overhead stays within ~10% in the smoke sweep.

Beyond schema validation the gate now *bites*: ``--compare BASELINE``
diffs the fresh sweep against a committed document and exits non-zero
when any matching ``(family, scenario, local_kernel, backend,
observables)`` row regresses ``updates_per_s`` by more than
``--regressionThreshold``
(fraction; CI uses 0.75 — generous because CPU-runner jitter is real,
but a genuine order-of-magnitude regression still fails the build).
``--history FILE`` appends the full document as one JSONL line (the
perf trajectory artifact CI uploads); ``--candidate FILE`` compares an
existing document instead of re-benchmarking.

Run:  [ESCG_BENCH_SMOKE=1] PYTHONPATH=src python -m benchmarks.bench_gate \
          [--out BENCH_kernels.json] [--compare BENCH_kernels.json] \
          [--regressionThreshold 0.75] [--history BENCH_history.jsonl]
      PYTHONPATH=src python -m benchmarks.bench_gate --validate FILE...
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import List, Optional

# must happen before the first jax import anywhere in the process
if os.environ.get("ESCG_FAKE_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count="
        + os.environ["ESCG_FAKE_DEVICES"])

SCHEMA = "escg-bench-kernels/v5"
SCHEMA_V4 = "escg-bench-kernels/v4"
SCHEMA_V3 = "escg-bench-kernels/v3"
# history lines from older gate versions stay valid against the schema
# they were written under (the trajectory spans schema bumps); fresh
# documents and compare baselines must carry the CURRENT schema
KNOWN_SCHEMAS = (SCHEMA_V3, SCHEMA_V4, SCHEMA)
# v5: the document additionally carries >= 1 family-"serve" derived row —
# serving throughput under the smoke trace (requests/s and Mupd/s from
# repro.serve.loadgen.gate_row) riding the same --history trajectory
SERVE_FAMILY = "serve"
FAMILIES = ("sublattice", "sharded", "sharded_pod")
LOCAL_KERNELS = ("jnp", "pallas", "fused")
# scenario-layer sweep (v2): park3 carries the full kernel x family grid;
# the other study presets pin the jnp kernel per family — the artifact
# must cover ALL of both tuples (validate_gate_document)
SCENARIOS = ("park3", "zhong_density", "nspecies5")
# the sublattice family is the single-device engine of each kernel lineage
SINGLE_ENGINE = {"jnp": "sublattice", "pallas": "pallas",
                 "fused": "pallas_fused"}


# ------------------------------ validation -------------------------------- #
# Hand-rolled (no jsonschema dependency); returns a list of human-readable
# errors, empty when valid. CI fails on any non-empty list.

def _check(obj: dict, field: str, types, errors: List[str],
           ctx: str) -> None:
    if field not in obj:
        errors.append(f"{ctx}: missing field {field!r}")
    elif not isinstance(obj[field], types):
        errors.append(f"{ctx}: field {field!r} has type "
                      f"{type(obj[field]).__name__}, want {types}")


def validate_row(obj, ctx: str = "row") -> List[str]:
    """The stdout BENCH_JSON row contract every benchmark module emits."""
    if not isinstance(obj, dict):
        return [f"{ctx}: not a JSON object"]
    errors: List[str] = []
    _check(obj, "name", str, errors, ctx)
    _check(obj, "us_per_call", (int, float), errors, ctx)
    _check(obj, "derived", str, errors, ctx)
    if not errors:
        if not obj["name"]:
            errors.append(f"{ctx}: empty name")
        if isinstance(obj["us_per_call"], bool) or obj["us_per_call"] <= 0:
            errors.append(f"{ctx}: us_per_call must be a positive number, "
                          f"got {obj['us_per_call']!r}")
    return errors


TIMING_FIELDS = ("median_us", "mean_us", "min_us", "max_us", "n")


def validate_serve_row(obj, ctx: str = "row") -> List[str]:
    """A family-``serve`` derived row (v5): serving throughput of a trace
    replay, not a kernel timing — no lattice/timing block, instead the
    request counters the serve-smoke CI job gates on."""
    errors = validate_row(obj, ctx)
    if not isinstance(obj, dict):
        return errors
    for fld in ("scenario", "local_kernel", "engine", "backend"):
        _check(obj, fld, str, errors, ctx)
    _check(obj, "observables", bool, errors, ctx)
    _check(obj, "n_requests", int, errors, ctx)
    _check(obj, "requests_per_s", (int, float), errors, ctx)
    _check(obj, "updates_per_s", (int, float), errors, ctx)
    _check(obj, "cache_hits", int, errors, ctx)
    _check(obj, "cache_misses", int, errors, ctx)
    _check(obj, "dropped", int, errors, ctx)
    if errors:
        return errors
    if obj["n_requests"] < 1:
        errors.append(f"{ctx}: serve row n_requests must be >= 1")
    if obj["requests_per_s"] <= 0 or obj["updates_per_s"] <= 0:
        errors.append(f"{ctx}: serve row throughput must be positive")
    if obj["cache_hits"] < 0 or obj["cache_misses"] < 0:
        errors.append(f"{ctx}: serve row cache counters must be >= 0")
    if obj["dropped"] != 0:
        errors.append(f"{ctx}: serve row dropped={obj['dropped']} — every "
                      "admitted request must be answered")
    return errors


def validate_gate_row(obj, ctx: str = "row",
                      schema: str = SCHEMA) -> List[str]:
    if isinstance(obj, dict) and obj.get("family") == SERVE_FAMILY:
        if schema in (SCHEMA_V3, SCHEMA_V4):
            return [f"{ctx}: family 'serve' rows require schema {SCHEMA} "
                    f"(document declares {schema})"]
        return validate_serve_row(obj, ctx)
    errors = validate_row(obj, ctx)
    if not isinstance(obj, dict):
        return errors
    _check(obj, "family", str, errors, ctx)
    _check(obj, "scenario", str, errors, ctx)
    _check(obj, "local_kernel", str, errors, ctx)
    _check(obj, "engine", str, errors, ctx)
    _check(obj, "backend", str, errors, ctx)
    if schema != SCHEMA_V3:                 # observables is new in v4
        _check(obj, "observables", bool, errors, ctx)
    _check(obj, "lattice", list, errors, ctx)
    _check(obj, "mcs", int, errors, ctx)
    _check(obj, "n_trials", int, errors, ctx)
    _check(obj, "n_pad", int, errors, ctx)
    _check(obj, "updates_per_s", (int, float), errors, ctx)
    _check(obj, "timing", dict, errors, ctx)
    if errors:
        return errors
    if obj["family"] not in FAMILIES:
        errors.append(f"{ctx}: family {obj['family']!r} not in {FAMILIES}")
    if obj["scenario"] not in SCENARIOS:
        errors.append(f"{ctx}: scenario {obj['scenario']!r} not in "
                      f"{SCENARIOS}")
    if obj["local_kernel"] not in LOCAL_KERNELS:
        errors.append(f"{ctx}: local_kernel {obj['local_kernel']!r} not in "
                      f"{LOCAL_KERNELS}")
    if (len(obj["lattice"]) != 2
            or not all(isinstance(v, int) and v > 0
                       for v in obj["lattice"])):
        errors.append(f"{ctx}: lattice must be [H, W] positive ints, got "
                      f"{obj['lattice']!r}")
    if obj["mcs"] < 0 or obj["n_trials"] < 0:
        errors.append(f"{ctx}: mcs/n_trials must be >= 0")
    if obj["n_pad"] < obj["n_trials"]:
        errors.append(f"{ctx}: n_pad ({obj['n_pad']}) < n_trials "
                      f"({obj['n_trials']}) — padding can only grow the "
                      "batch")
    if obj["updates_per_s"] < 0:
        errors.append(f"{ctx}: updates_per_s must be >= 0")
    for fld in TIMING_FIELDS:
        v = obj["timing"].get(fld)
        if not isinstance(v, (int, float)) or isinstance(v, bool) or v <= 0:
            errors.append(f"{ctx}: timing[{fld!r}] must be a positive "
                          f"number, got {v!r}")
    if not errors and obj["timing"]["min_us"] > obj["timing"]["max_us"]:
        errors.append(f"{ctx}: timing min_us > max_us")
    return errors


def validate_gate_document(doc, accept=(SCHEMA,)) -> List[str]:
    """The BENCH_kernels.json artifact the perf-smoke CI job uploads.

    ``accept`` is the set of schema versions tolerated: fresh documents
    and compare baselines require the current schema (the default);
    ``validate_file`` passes KNOWN_SCHEMAS for history lines so older
    trajectory entries keep validating against the schema they declare."""
    if not isinstance(doc, dict):
        return ["document: not a JSON object"]
    errors: List[str] = []
    schema = doc.get("schema")
    if schema not in accept:
        errors.append(f"document: schema {schema!r} not in {accept!r}")
        schema = SCHEMA
    _check(doc, "backend", str, errors, "document")
    _check(doc, "devices", int, errors, "document")
    _check(doc, "smoke", bool, errors, "document")
    _check(doc, "rows", list, errors, "document")
    if errors:
        return errors
    if doc["devices"] < 1:
        errors.append("document: devices must be >= 1")
    if not doc["rows"]:
        errors.append("document: rows is empty")
    for i, row in enumerate(doc["rows"]):
        errors.extend(validate_gate_row(row, ctx=f"rows[{i}]",
                                        schema=schema))
    for fld, want in (("local_kernel", LOCAL_KERNELS),
                      ("scenario", SCENARIOS)):
        covered = {r.get(fld) for r in doc["rows"] if isinstance(r, dict)}
        missing = set(want) - covered
        if missing:
            errors.append(f"document: rows cover {fld}s {sorted(covered)} "
                          f"— missing {sorted(missing)} (all of {want} "
                          "are required)")
    if schema == SCHEMA and not any(
            isinstance(r, dict) and r.get("family") == SERVE_FAMILY
            for r in doc["rows"]):
        errors.append(f"document: {SCHEMA} requires at least one "
                      "family-'serve' derived row (serving throughput "
                      "under the smoke trace)")
    return errors


def validate_file(path: str) -> List[str]:
    """Validate a BENCH_kernels.json document, a BENCH_history.jsonl
    trajectory (one gate *document* per line), or a BENCH_JSON row stream
    (one row object per line; blank and '#' lines are ignored). History
    and row lines may be mixed — each line is dispatched on the presence
    of a ``schema`` field."""
    with open(path) as f:
        text = f.read()
    try:
        doc = json.loads(text)
    except json.JSONDecodeError:
        doc = None
    if isinstance(doc, dict) and "schema" in doc:
        return [f"{path}: {e}" for e in validate_gate_document(doc)]
    errors: List[str] = []
    rows = 0
    for ln_no, line in enumerate(text.splitlines(), 1):
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        try:
            obj = json.loads(line)
        except json.JSONDecodeError as e:
            errors.append(f"{path}:{ln_no}: not JSON ({e})")
            continue
        rows += 1
        if isinstance(obj, dict) and "schema" in obj:
            errors.extend(f"{path}:{ln_no}: {e}"
                          for e in validate_gate_document(
                              obj, accept=KNOWN_SCHEMAS))
        else:
            errors.extend(validate_row(obj, ctx=f"{path}:{ln_no}"))
    if rows == 0:
        errors.append(f"{path}: no benchmark rows found")
    return errors


# ------------------------- trajectory gating ------------------------------ #

def row_key(row: dict):
    """The identity a perf trajectory tracks: what ran and where, never
    how fast. Lattice size / MCS / trial counts are deliberately NOT part
    of the key — those change with sweep sizing, and the smoke guard in
    ``compare_documents`` keeps apples with apples. ``observables`` IS
    part of the key (v4): an obs-on row is a different workload than its
    off twin and must only ever gate against another obs-on row."""
    return (row.get("family"), row.get("scenario"),
            row.get("local_kernel"), row.get("backend"),
            bool(row.get("observables")))


def compare_documents(candidate: dict, baseline: dict,
                      threshold: float) -> List[str]:
    """Regression diff of two gate documents; returns human-readable
    failures (empty = gate passes).

    A matching ``(family, scenario, local_kernel, backend, observables)``
    row regresses
    when ``candidate.updates_per_s < baseline.updates_per_s * (1 -
    threshold)``. Documents with different ``smoke`` flags are
    incomparable (different sweep sizes) and compare clean with a note;
    an invalid baseline fails loudly — a gate diffing against garbage
    would silently pass forever."""
    if not 0.0 < threshold < 1.0:
        return [f"regression threshold must be in (0, 1), got {threshold}"]
    base_errors = validate_gate_document(baseline)
    if base_errors:
        return [f"baseline invalid: {e}" for e in base_errors]
    if bool(candidate.get("smoke")) != bool(baseline.get("smoke")):
        print("# compare: smoke flags differ (candidate "
              f"{candidate.get('smoke')} vs baseline "
              f"{baseline.get('smoke')}) — sweeps incomparable, skipping",
              file=sys.stderr)
        return []
    base_rows = {row_key(r): r for r in baseline["rows"]}
    failures: List[str] = []
    matched = 0
    for row in candidate.get("rows", []):
        base = base_rows.get(row_key(row))
        if base is None:
            continue
        matched += 1
        floor = base["updates_per_s"] * (1.0 - threshold)
        if row["updates_per_s"] < floor:
            failures.append(
                f"{row['name']}: {row['updates_per_s']:.1f} upd/s < "
                f"{floor:.1f} (baseline {base['updates_per_s']:.1f}, "
                f"threshold {threshold:.0%})")
    if matched == 0:
        failures.append(
            "no candidate row matches any baseline (family, scenario, "
            "local_kernel, backend, observables) key — the gate compared "
            "nothing")
    return failures


def append_history(doc: dict, path: str) -> None:
    """Append the full gate document as one JSONL line — the perf
    trajectory artifact (validated by ``validate_file``; CI uploads it
    every perf-smoke run)."""
    with open(path, "a") as f:
        f.write(json.dumps(doc, separators=(",", ":")) + "\n")


# -------------------------------- sweep ----------------------------------- #

# the obs-on rows stream the park3 scenario observable set (DESIGN.md
# §11): per-species densities plus the interface-length order parameter —
# the pairing the overhead acceptance criterion is defined over
OBS_SET = ("densities", "interface_length")


def _gate_config(family: str, kernel: str, scenario: str,
                 observables: bool = False):
    """(EscgParams, Scenario) for one sweep cell — a scenario-layer
    composition: physics from the registered preset (mobility pinned to
    1e-4 and empty to 0.1 so occupancy is comparable across studies),
    engine/run from the cell. ``observables=True`` turns on the
    device-ring observable pipeline (OBS_SET) for the overhead rows."""
    from repro.core.scenarios import (EngineConfig, RunConfig, compose,
                                      make_scenario)
    from .common import smoke
    L = smoke(32, 64)
    h = smoke(16, 64)
    if family == "sublattice":
        engine, lk = SINGLE_ENGINE[kernel], "jnp"   # knob ignored
    else:
        engine, lk = family, kernel
    sc = make_scenario(scenario).replace(mobility=1e-4, empty=0.1)
    p = compose(sc, EngineConfig(engine=engine, local_kernel=lk,
                                 tile=(8, 16)),
                RunConfig(length=L, height=h, seed=0,
                          observables=OBS_SET if observables else ()))
    return p, sc


def _bench_combo(family: str, kernel: str, scenario: str, mcs: int,
                 trials: int, observables: bool = False) -> dict:
    """Per-call timing stats of one jitted chunk (compile excluded, like
    fig4_3): a simulate() chunk for the one-lattice families, a
    run_trials chunk for the composed family. With ``observables=True``
    the chunk is the observable-pipeline variant (DESIGN.md §11): same
    dynamics, but every MCS also banks an OBS_SET row into the
    device-resident ring buffer — the timing delta against the off twin
    IS the observable overhead the gate records.

    Throughput normalization (the v2 bug this schema fixes): the
    composed family pads the trial batch to the pod width, so the kernel
    *runs* ``n_pad`` lattices — but ``updates_per_s`` counts only the
    ``n_trials`` REQUESTED lattices. Normalizing over padding made the
    same workload look faster on wider pods (free throughput from wasted
    work); both counts now land in the row so either view is
    recoverable."""
    import jax
    import jax.numpy as jnp

    from repro.core import engines
    from repro.core import observables as obs_mod
    from repro.core.lattice import init_grid
    from .common import time_stats

    p, sc = _gate_config(family, kernel, scenario, observables=observables)
    dom = jnp.asarray(sc.dominance(), jnp.float32)
    built = engines.build(p, dom)
    if family == "sharded_pod":
        from repro.core.trials import (build_trial_chunk,
                                       build_trial_obs_chunk, pad_trials,
                                       trial_grids_and_keys)
        n_trials = trials
        n_pad = pad_trials(n_trials, built.pod_width)
        grids, keys = trial_grids_and_keys(
            p, jax.random.PRNGKey(0), n_pad, sharding=built.key_sharding,
            grid_sharding=built.batch_sharding)
        if observables:
            chunk, pipe = build_trial_obs_chunk(p, dom, built=built)
            ring, pos = obs_mod.ring_init(
                obs_mod.ring_capacity(p, mcs), (n_pad, pipe.width))
            stats = time_stats(lambda: chunk(grids, keys, ring, pos, mcs),
                               warmup=2, iters=9)
        else:
            chunk = build_trial_chunk(p, dom, built=built)
            stats = time_stats(lambda: chunk(grids, keys, mcs),
                               warmup=2, iters=9)
        n_upd = mcs * p.n_cells * n_trials
    else:
        from repro.core.simulation import build_chunk_fn, build_obs_chunk_fn
        grid = init_grid(jax.random.PRNGKey(0), p.height, p.length,
                         p.species, p.empty)
        if built.grid_sharding is not None:
            grid = jax.device_put(grid, built.grid_sharding)
        if observables:
            chunk, pipe = build_obs_chunk_fn(p, dom, built=built)
            ring, pos = obs_mod.ring_init(
                obs_mod.ring_capacity(p, mcs), (pipe.width,))
            stats = time_stats(
                lambda: chunk(grid, jax.random.PRNGKey(1), ring, pos, mcs),
                warmup=2, iters=9)
        else:
            chunk = build_chunk_fn(p, dom, one_mcs=built.one_mcs)
            stats = time_stats(
                lambda: chunk(grid, jax.random.PRNGKey(1), mcs),
                warmup=2, iters=9)
        n_upd = mcs * p.n_cells
        n_trials = n_pad = 0
    t = stats["median_us"] / 1e6
    upd_s = n_upd / t
    suffix = "_obs" if observables else ""
    return {
        "name": f"kernelgate_{scenario}_{family}_{kernel}{suffix}",
        "us_per_call": stats["median_us"],
        "derived": f"{upd_s / 1e6:.3f} Mupd/s engine={p.engine} "
                   f"scenario={scenario}",
        "family": family,
        "scenario": scenario,
        "local_kernel": kernel,
        "engine": p.engine,
        "backend": jax.default_backend(),
        "observables": bool(observables),
        "lattice": [p.height, p.length],
        "mcs": mcs,
        "n_trials": n_trials,
        "n_pad": n_pad,
        "updates_per_s": round(upd_s, 1),
        "timing": stats,
    }


SMOKE_TRACE = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "examples", "traces", "smoke.jsonl")


def _serve_row() -> dict:
    """The v5 ``serve_throughput`` derived row: replay the committed
    smoke trace (synthetic fallback) through an in-process
    ``ScenarioServer`` and reshape the report via ``loadgen.gate_row`` —
    serving throughput rides the same trajectory as the kernel rows."""
    from repro.serve import loadgen
    from repro.serve.server import ScenarioServer

    from .common import note

    reqs = (loadgen.read_trace(SMOKE_TRACE) if os.path.exists(SMOKE_TRACE)
            else loadgen.synthetic_trace(10, 0))
    report = loadgen.replay(ScenarioServer(), reqs, waves=2)
    problems = loadgen.check_report(report)
    if problems:
        raise SystemExit("bench_gate serve replay failed its acceptance "
                         "checks:\n" + "\n".join(problems))
    note(f"serve: {report['n_requests']} requests "
         f"{report['requests_per_s']:.2f} req/s, cache "
         f"{report['cache']['hits']}H/{report['cache']['misses']}M")
    return loadgen.gate_row(report)


def run(out_path: Optional[str] = None) -> dict:
    import jax

    from .common import SMOKE, emit, note, smoke

    # 16 MCS even in smoke: the observable-overhead pairs measure a ~5%
    # timing delta, which 2-MCS µs-scale calls bury in CPU jitter (scan
    # compile time is length-independent, so the longer chunk costs CI
    # nothing); _bench_combo's iters=9 median serves the same purpose
    mcs = smoke(16, 16)
    trials = smoke(2, 4)
    note(f"kernel gate: {LOCAL_KERNELS} x {FAMILIES} on scenario "
         f"{SCENARIOS[0]!r}, + scenarios {SCENARIOS[1:]} per family "
         f"(jnp), + observable-overhead pairs per family, {mcs} MCS "
         f"({len(jax.devices())} device(s))")
    combos = [(family, kernel, SCENARIOS[0], False)
              for family in FAMILIES for kernel in LOCAL_KERNELS]
    combos += [(family, "jnp", scenario, False)
               for scenario in SCENARIOS[1:] for family in FAMILIES]
    # observable-overhead pairs (v4): the on-rows; their off twins are
    # already in the park3 grid above — row_key pairs them by identity
    combos += [(family, "jnp", SCENARIOS[0], True) for family in FAMILIES]
    rows = []
    for family, kernel, scenario, obs in combos:
        row = _bench_combo(family, kernel, scenario, mcs, trials,
                           observables=obs)
        if obs:
            # annotate the on-row with the measured overhead vs its twin
            twin_key = row_key({**row, "observables": False})
            twin = next(r for r in rows if row_key(r) == twin_key)
            overhead = (twin["updates_per_s"] / row["updates_per_s"]
                        - 1.0) if row["updates_per_s"] else float("inf")
            row["derived"] += f" obs_overhead={overhead:+.1%}"
            note(f"observable overhead {family}/{kernel}: {overhead:+.1%} "
                 f"({twin['updates_per_s']:.0f} -> "
                 f"{row['updates_per_s']:.0f} upd/s)")
        rows.append(row)
        emit(row["name"], row["us_per_call"] / 1e6, row["derived"])
    rows.append(_serve_row())
    emit(rows[-1]["name"], rows[-1]["us_per_call"] / 1e6,
         rows[-1]["derived"])
    doc = {
        "schema": SCHEMA,
        "backend": jax.default_backend(),
        "devices": len(jax.devices()),
        "smoke": bool(SMOKE),
        "unix_time": int(time.time()),
        "rows": rows,
    }
    errors = validate_gate_document(doc)
    if errors:                  # the gate gates itself first
        raise SystemExit("bench_gate produced a schema-invalid document:\n"
                         + "\n".join(errors))
    out_path = out_path or os.environ.get("BENCH_GATE_OUT")
    if out_path:
        with open(out_path, "w") as f:
            json.dump(doc, f, indent=1)
        note(f"schema-valid {SCHEMA} document -> {out_path}")
    return doc


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default=None,
                    help="write the BENCH_kernels.json artifact here "
                         "(default: $BENCH_GATE_OUT, or no file)")
    ap.add_argument("--validate", nargs="+", metavar="FILE", default=None,
                    help="validate BENCH_kernels.json documents, "
                         "BENCH_history.jsonl trajectories and/or "
                         "BENCH_JSON row streams instead of benchmarking")
    ap.add_argument("--compare", metavar="BASELINE", default=None,
                    help="diff the sweep against this committed gate "
                         "document; exit non-zero on any matching-row "
                         "updates_per_s regression beyond the threshold")
    ap.add_argument("--candidate", metavar="FILE", default=None,
                    help="with --compare: read the candidate document "
                         "from FILE instead of re-running the sweep")
    ap.add_argument("--regressionThreshold", dest="regression_threshold",
                    type=float, default=0.5,
                    help="fractional updates_per_s drop that fails the "
                         "gate (default 0.5 = fail below half the "
                         "baseline; CI passes 0.75)")
    ap.add_argument("--history", metavar="FILE", default=None,
                    help="append the gate document to this "
                         "BENCH_history.jsonl perf trajectory")
    args = ap.parse_args()
    if args.validate:
        all_errors = []
        for path in args.validate:
            all_errors.extend(validate_file(path))
        if all_errors:
            print("\n".join(all_errors), file=sys.stderr)
            raise SystemExit(1)
        print(f"# {len(args.validate)} file(s) schema-valid",
              file=sys.stderr)
        return
    # read the baseline BEFORE the sweep runs, so `--out X --compare X`
    # means "diff this run against the committed snapshot, then refresh
    # it" — the natural CI invocation — instead of a vacuous self-compare
    baseline = None
    if args.compare:
        with open(args.compare) as f:
            baseline = json.load(f)
    if args.candidate:
        if not args.compare:
            ap.error("--candidate requires --compare")
        with open(args.candidate) as f:
            doc = json.load(f)
        errors = validate_gate_document(doc)
        if errors:
            print("\n".join(f"candidate invalid: {e}" for e in errors),
                  file=sys.stderr)
            raise SystemExit(1)
    else:
        doc = run(out_path=args.out)
    # artifacts land BEFORE the gate can fail: a regressed run must still
    # leave its evidence on disk / in the uploaded trajectory
    if args.history:
        append_history(doc, args.history)
        print(f"# trajectory entry -> {args.history}", file=sys.stderr)
    if args.compare:
        failures = compare_documents(doc, baseline,
                                     args.regression_threshold)
        if failures:
            print("PERF GATE FAILED vs " + args.compare, file=sys.stderr)
            print("\n".join(failures), file=sys.stderr)
            raise SystemExit(1)
        print(f"# perf gate clean vs {args.compare} (threshold "
              f"{args.regression_threshold:.0%})", file=sys.stderr)


if __name__ == "__main__":
    main()
