"""Perf gate — local-kernel x engine-family sweep with a schema-checked
JSON artifact (DESIGN.md §7).

The paper's headline result (Fig 4.2 / §3.2.1) is that eliminating the
materialized random-number buffer is the step that turns the update loop
bandwidth-bound: our ``fused`` local kernel is exactly that move, now
available inside the sharded engines' shard_map regions. This module is
the CI-tracked evidence: it sweeps every local kernel {jnp, pallas,
fused} across every engine family {sublattice, sharded, sharded_pod} and
writes ``BENCH_kernels.json`` — the artifact the ``perf-smoke`` CI job
validates and uploads every run, seeding the perf trajectory.

Stdout keeps the common benchmark contract (``name,us_per_call,derived``
CSV rows, or one JSON object per row under ``BENCH_JSON=1``); the richer
per-row fields land in the artifact. Both formats are validated by the
functions below (also exposed as ``--validate FILE...`` for CI):

* a *row* must carry ``name`` (non-empty str), ``us_per_call`` (number
  > 0) and ``derived`` (str);
* the *document* must carry ``schema == "escg-bench-kernels/v2"``,
  ``backend``/``devices``/``smoke`` metadata and a non-empty ``rows``
  list whose entries extend the row schema with ``family``,
  ``scenario`` (the registered scenario-layer preset the cell ran,
  DESIGN.md §10 — new in v2), ``local_kernel``, ``engine``, ``lattice``
  ([H, W]), ``mcs``, ``trials`` and ``updates_per_s`` — and whose rows
  must cover ALL three local kernels AND all three swept scenarios
  {park3, zhong_density, nspecies5} (the acceptance criterion; a sweep
  that silently drops one fails validation, not review).

Run:  [ESCG_BENCH_SMOKE=1] PYTHONPATH=src python -m benchmarks.bench_gate \
          [--out BENCH_kernels.json]
      PYTHONPATH=src python -m benchmarks.bench_gate --validate FILE...
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List, Optional

# must happen before the first jax import anywhere in the process
if os.environ.get("ESCG_FAKE_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count="
        + os.environ["ESCG_FAKE_DEVICES"])

SCHEMA = "escg-bench-kernels/v2"
FAMILIES = ("sublattice", "sharded", "sharded_pod")
LOCAL_KERNELS = ("jnp", "pallas", "fused")
# scenario-layer sweep (v2): park3 carries the full kernel x family grid;
# the other study presets pin the jnp kernel per family — the artifact
# must cover ALL of both tuples (validate_gate_document)
SCENARIOS = ("park3", "zhong_density", "nspecies5")
# the sublattice family is the single-device engine of each kernel lineage
SINGLE_ENGINE = {"jnp": "sublattice", "pallas": "pallas",
                 "fused": "pallas_fused"}


# ------------------------------ validation -------------------------------- #
# Hand-rolled (no jsonschema dependency); returns a list of human-readable
# errors, empty when valid. CI fails on any non-empty list.

def _check(obj: dict, field: str, types, errors: List[str],
           ctx: str) -> None:
    if field not in obj:
        errors.append(f"{ctx}: missing field {field!r}")
    elif not isinstance(obj[field], types):
        errors.append(f"{ctx}: field {field!r} has type "
                      f"{type(obj[field]).__name__}, want {types}")


def validate_row(obj, ctx: str = "row") -> List[str]:
    """The stdout BENCH_JSON row contract every benchmark module emits."""
    if not isinstance(obj, dict):
        return [f"{ctx}: not a JSON object"]
    errors: List[str] = []
    _check(obj, "name", str, errors, ctx)
    _check(obj, "us_per_call", (int, float), errors, ctx)
    _check(obj, "derived", str, errors, ctx)
    if not errors:
        if not obj["name"]:
            errors.append(f"{ctx}: empty name")
        if isinstance(obj["us_per_call"], bool) or obj["us_per_call"] <= 0:
            errors.append(f"{ctx}: us_per_call must be a positive number, "
                          f"got {obj['us_per_call']!r}")
    return errors


def validate_gate_row(obj, ctx: str = "row") -> List[str]:
    errors = validate_row(obj, ctx)
    if not isinstance(obj, dict):
        return errors
    _check(obj, "family", str, errors, ctx)
    _check(obj, "scenario", str, errors, ctx)
    _check(obj, "local_kernel", str, errors, ctx)
    _check(obj, "engine", str, errors, ctx)
    _check(obj, "lattice", list, errors, ctx)
    _check(obj, "mcs", int, errors, ctx)
    _check(obj, "trials", int, errors, ctx)
    _check(obj, "updates_per_s", (int, float), errors, ctx)
    if errors:
        return errors
    if obj["family"] not in FAMILIES:
        errors.append(f"{ctx}: family {obj['family']!r} not in {FAMILIES}")
    if obj["scenario"] not in SCENARIOS:
        errors.append(f"{ctx}: scenario {obj['scenario']!r} not in "
                      f"{SCENARIOS}")
    if obj["local_kernel"] not in LOCAL_KERNELS:
        errors.append(f"{ctx}: local_kernel {obj['local_kernel']!r} not in "
                      f"{LOCAL_KERNELS}")
    if (len(obj["lattice"]) != 2
            or not all(isinstance(v, int) and v > 0
                       for v in obj["lattice"])):
        errors.append(f"{ctx}: lattice must be [H, W] positive ints, got "
                      f"{obj['lattice']!r}")
    if obj["mcs"] < 0 or obj["trials"] < 0:
        errors.append(f"{ctx}: mcs/trials must be >= 0")
    if obj["updates_per_s"] < 0:
        errors.append(f"{ctx}: updates_per_s must be >= 0")
    return errors


def validate_gate_document(doc) -> List[str]:
    """The BENCH_kernels.json artifact the perf-smoke CI job uploads."""
    if not isinstance(doc, dict):
        return ["document: not a JSON object"]
    errors: List[str] = []
    if doc.get("schema") != SCHEMA:
        errors.append(f"document: schema {doc.get('schema')!r} != {SCHEMA!r}")
    _check(doc, "backend", str, errors, "document")
    _check(doc, "devices", int, errors, "document")
    _check(doc, "smoke", bool, errors, "document")
    _check(doc, "rows", list, errors, "document")
    if errors:
        return errors
    if doc["devices"] < 1:
        errors.append("document: devices must be >= 1")
    if not doc["rows"]:
        errors.append("document: rows is empty")
    for i, row in enumerate(doc["rows"]):
        errors.extend(validate_gate_row(row, ctx=f"rows[{i}]"))
    for fld, want in (("local_kernel", LOCAL_KERNELS),
                      ("scenario", SCENARIOS)):
        covered = {r.get(fld) for r in doc["rows"] if isinstance(r, dict)}
        missing = set(want) - covered
        if missing:
            errors.append(f"document: rows cover {fld}s {sorted(covered)} "
                          f"— missing {sorted(missing)} (all of {want} "
                          "are required)")
    return errors


def validate_file(path: str) -> List[str]:
    """Validate a BENCH_kernels.json document or a BENCH_JSON row stream
    (one JSON object per line; blank and '#' lines are ignored)."""
    with open(path) as f:
        text = f.read()
    try:
        doc = json.loads(text)
    except json.JSONDecodeError:
        doc = None
    if isinstance(doc, dict) and "schema" in doc:
        return [f"{path}: {e}" for e in validate_gate_document(doc)]
    errors: List[str] = []
    rows = 0
    for ln_no, line in enumerate(text.splitlines(), 1):
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        try:
            obj = json.loads(line)
        except json.JSONDecodeError as e:
            errors.append(f"{path}:{ln_no}: not JSON ({e})")
            continue
        rows += 1
        errors.extend(validate_row(obj, ctx=f"{path}:{ln_no}"))
    if rows == 0:
        errors.append(f"{path}: no benchmark rows found")
    return errors


# -------------------------------- sweep ----------------------------------- #

def _gate_config(family: str, kernel: str, scenario: str):
    """(EscgParams, Scenario) for one sweep cell — a scenario-layer
    composition: physics from the registered preset (mobility pinned to
    1e-4 and empty to 0.1 so occupancy is comparable across studies),
    engine/run from the cell."""
    from repro.core.scenarios import (EngineConfig, RunConfig, compose,
                                      make_scenario)
    from .common import smoke
    L = smoke(32, 64)
    h = smoke(16, 64)
    if family == "sublattice":
        engine, lk = SINGLE_ENGINE[kernel], "jnp"   # knob ignored
    else:
        engine, lk = family, kernel
    sc = make_scenario(scenario).replace(mobility=1e-4, empty=0.1)
    p = compose(sc, EngineConfig(engine=engine, local_kernel=lk,
                                 tile=(8, 16)),
                RunConfig(length=L, height=h, seed=0))
    return p, sc


def _bench_combo(family: str, kernel: str, scenario: str, mcs: int,
                 trials: int) -> dict:
    """Median time of one jitted chunk (compile excluded, like fig4_3):
    a simulate() chunk for the one-lattice families, a run_trials chunk
    for the composed family."""
    import jax
    import jax.numpy as jnp

    from repro.core import engines
    from repro.core.lattice import init_grid
    from .common import time_fn

    p, sc = _gate_config(family, kernel, scenario)
    dom = jnp.asarray(sc.dominance(), jnp.float32)
    built = engines.build(p, dom)
    if family == "sharded_pod":
        from repro.core.trials import (build_trial_chunk, pad_trials,
                                       trial_grids_and_keys)
        n_pad = pad_trials(trials, built.pod_width)
        grids, keys = trial_grids_and_keys(
            p, jax.random.PRNGKey(0), n_pad, sharding=built.key_sharding,
            grid_sharding=built.batch_sharding)
        chunk = build_trial_chunk(p, dom, built=built)
        t = time_fn(lambda: chunk(grids, keys, mcs), warmup=1, iters=2)
        n_upd = mcs * p.n_cells * n_pad
        trials = n_pad          # report what actually ran: the padded
                                # batch is the throughput base, and it
                                # varies with the pod width across runners
    else:
        from repro.core.simulation import build_chunk_fn
        chunk = build_chunk_fn(p, dom, one_mcs=built.one_mcs)
        grid = init_grid(jax.random.PRNGKey(0), p.height, p.length,
                         p.species, p.empty)
        if built.grid_sharding is not None:
            grid = jax.device_put(grid, built.grid_sharding)
        t = time_fn(lambda: chunk(grid, jax.random.PRNGKey(1), mcs),
                    warmup=1, iters=2)
        n_upd = mcs * p.n_cells
        trials = 0
    upd_s = n_upd / t
    return {
        "name": f"kernelgate_{scenario}_{family}_{kernel}",
        "us_per_call": round(t * 1e6, 1),
        "derived": f"{upd_s / 1e6:.3f} Mupd/s engine={p.engine} "
                   f"scenario={scenario}",
        "family": family,
        "scenario": scenario,
        "local_kernel": kernel,
        "engine": p.engine,
        "lattice": [p.height, p.length],
        "mcs": mcs,
        "trials": trials,
        "updates_per_s": round(upd_s, 1),
    }


def run(out_path: Optional[str] = None) -> dict:
    import jax

    from .common import SMOKE, emit, note, smoke

    mcs = smoke(2, 10)
    trials = smoke(2, 4)
    note(f"kernel gate: {LOCAL_KERNELS} x {FAMILIES} on scenario "
         f"{SCENARIOS[0]!r}, + scenarios {SCENARIOS[1:]} per family "
         f"(jnp), {mcs} MCS ({len(jax.devices())} device(s))")
    combos = [(family, kernel, SCENARIOS[0])
              for family in FAMILIES for kernel in LOCAL_KERNELS]
    combos += [(family, "jnp", scenario)
               for scenario in SCENARIOS[1:] for family in FAMILIES]
    rows = []
    for family, kernel, scenario in combos:
        row = _bench_combo(family, kernel, scenario, mcs, trials)
        rows.append(row)
        emit(row["name"], row["us_per_call"] / 1e6, row["derived"])
    doc = {
        "schema": SCHEMA,
        "backend": jax.default_backend(),
        "devices": len(jax.devices()),
        "smoke": bool(SMOKE),
        "rows": rows,
    }
    errors = validate_gate_document(doc)
    if errors:                  # the gate gates itself first
        raise SystemExit("bench_gate produced a schema-invalid document:\n"
                         + "\n".join(errors))
    out_path = out_path or os.environ.get("BENCH_GATE_OUT")
    if out_path:
        with open(out_path, "w") as f:
            json.dump(doc, f, indent=1)
        note(f"schema-valid {SCHEMA} document -> {out_path}")
    return doc


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default=None,
                    help="write the BENCH_kernels.json artifact here "
                         "(default: $BENCH_GATE_OUT, or no file)")
    ap.add_argument("--validate", nargs="+", metavar="FILE", default=None,
                    help="validate BENCH_kernels.json documents and/or "
                         "BENCH_JSON row streams instead of benchmarking")
    args = ap.parse_args()
    if args.validate:
        all_errors = []
        for path in args.validate:
            all_errors.extend(validate_file(path))
        if all_errors:
            print("\n".join(all_errors), file=sys.stderr)
            raise SystemExit(1)
        print(f"# {len(args.validate)} file(s) schema-valid",
              file=sys.stderr)
        return
    run(out_path=args.out)


if __name__ == "__main__":
    main()
