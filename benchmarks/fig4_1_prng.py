"""Paper Fig 4.1 — PRNG throughput for a large batch of random numbers.

Paper: 1e9 numbers; single-threaded MT 6.89s vs CUDA curand 0.57s (12.1x).
Here (CPU container, reduced N): single-threaded numpy MT19937 (the paper's
baseline PRNG) vs jax threefry (device-resident counter PRNG, the curand
analog) vs the Pallas Philox kernel (interpret mode on CPU — its TPU
performance is structural, not measurable here).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .common import emit, note, smoke, time_fn

N = smoke(500_000, 20_000_000)


def run(n: int = N) -> None:
    note(f"PRNG batch generation of {n:,} uint32 (paper Fig 4.1)")

    # single-threaded Mersenne Twister (paper's baseline)
    rs = np.random.RandomState(0)                       # MT19937
    t_mt = time_fn(lambda: rs.randint(0, 2**31, size=n, dtype=np.int64),
                   warmup=0, iters=3)
    emit("prng_mt19937_numpy_serial", t_mt, f"{n / t_mt / 1e6:.0f} M/s")

    # jax threefry, jitted + device resident (curand analog)
    gen = jax.jit(lambda key: jax.random.bits(key, (n,), jnp.uint32))
    key = jax.random.PRNGKey(0)
    t_tf = time_fn(gen, key)
    emit("prng_threefry_jax", t_tf, f"{n / t_tf / 1e6:.0f} M/s")

    # Pallas Philox kernel — interpret mode (CPU correctness harness)
    from repro.kernels import ops
    n_small = min(n, smoke(100_000, 1_000_000))  # interpreter is slow
    t_px = time_fn(lambda: ops.philox_bits(n_small, seed=(0, 1)),
                   warmup=1, iters=1)
    emit("prng_philox_pallas_interpret", t_px,
         f"{n_small / t_px / 1e6:.1f} M/s (interpret; N={n_small})")

    note(f"speedup threefry vs MT serial: {t_mt / t_tf:.1f}x "
         f"(paper: 12.1x curand vs MT at 1e9 on GPU)")


if __name__ == "__main__":
    run()
