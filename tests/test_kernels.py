"""Pallas kernels vs pure-jnp oracles (interpret mode), with shape sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dominance as dm
from repro.core.lattice import init_grid
from repro.core.rng import tile_proposal_batch
from repro.kernels import ops, ref

KNOWN_ANSWER = {
    # Random123 published KAT: philox4x32-10, zero counter / zero key
    (0, 0): (0x6627E8D5, 0xE169C58D, 0xBC57AC4C, 0x9B00DBD8),
}


# ------------------------------- philox ---------------------------------- #

def test_philox_known_answer():
    x = ref.philox4x32_ref(np.zeros(1, np.uint32), np.zeros(1, np.uint32),
                           np.zeros(1, np.uint32), np.zeros(1, np.uint32),
                           0, 0)
    got = tuple(int(v[0]) for v in x)
    assert got == KNOWN_ANSWER[(0, 0)]


@pytest.mark.parametrize("n", [1, 4, 100, 4096, 5000])
@pytest.mark.parametrize("seed", [(0, 0), (0xDEADBEEF, 0x12345678)])
def test_philox_kernel_matches_ref(n, seed):
    got = np.asarray(ops.philox_bits(n, seed=seed, stream=3, block=256))
    want = ref.philox_bits_ref(n, seed, stream=3, block=256)
    assert got.shape == (n,)
    np.testing.assert_array_equal(got, want)


def test_philox_uniform_range_and_mean():
    u = np.asarray(ops.philox_uniform(200_000, seed=(1, 2)))
    assert u.min() >= 0.0 and u.max() < 1.0
    assert abs(u.mean() - 0.5) < 0.005
    assert abs(u.var() - 1 / 12) < 0.005


def test_philox_streams_decorrelated():
    a = np.asarray(ops.philox_bits(10_000, seed=(5, 5), stream=0))
    b = np.asarray(ops.philox_bits(10_000, seed=(5, 5), stream=1))
    assert not np.array_equal(a, b)
    # correlation of uniforms ~ 0
    ua, ub = a / 2**32, b / 2**32
    assert abs(np.corrcoef(ua, ub)[0, 1]) < 0.05


# ----------------------------- escg update ------------------------------- #

@pytest.mark.parametrize("hw,tile,species,nbhd", [
    ((16, 32), (8, 16), 3, 4),
    ((24, 24), (8, 8), 5, 8),
    ((8, 128), (4, 32), 2, 4),
    ((32, 64), (16, 16), 8, 4),
])
def test_escg_kernel_matches_oracle(hw, tile, species, nbhd):
    h, w = hw
    th, tw = tile
    key = jax.random.PRNGKey(h * w + species)
    grid = init_grid(key, h, w, species, 0.15)
    offs = (1, 2) if species >= 5 else (1,)
    dom = jnp.asarray(dm.circulant(species, offs) if species > 1 else
                      dm.from_dense(np.zeros((1, 1), np.float32)))
    nt = (h // th) * (w // tw)
    k = 53
    props = tile_proposal_batch(jax.random.PRNGKey(1), nt, k,
                                (th - 2) * (tw - 2), nbhd)
    te, tem = 0.25, 0.6
    shift = jnp.array([th // 2, tw // 3], jnp.int32)
    got = ops.escg_round(grid, props, shift, dom, tile, te, tem)
    rolled = jnp.roll(grid, (-shift[0], -shift[1]), (0, 1))
    want = ref.escg_tile_round_ref(rolled, props.cell, props.dirn,
                                   props.u_act, props.u_dom, dom, tile, te,
                                   tem)
    want = jnp.roll(want, (shift[0], shift[1]), (0, 1))
    assert jnp.array_equal(got, want)


def test_escg_kernel_probabilistic_dominance():
    """Park-style fractional rates flow through the kernel identically."""
    h, w, th, tw = 16, 16, 8, 8
    grid = init_grid(jax.random.PRNGKey(0), h, w, 8, 0.0)
    dom = jnp.asarray(dm.park_alliance_network(0.3, 0.75, 1.0))
    props = tile_proposal_batch(jax.random.PRNGKey(2), 4, 40,
                                (th - 2) * (tw - 2), 4)
    shift = jnp.array([0, 0], jnp.int32)
    got = ops.escg_round(grid, props, shift, dom, (th, tw), 0.0, 0.9)
    want = ref.escg_tile_round_ref(grid, props.cell, props.dirn,
                                   props.u_act, props.u_dom, dom, (th, tw),
                                   0.0, 0.9)
    assert jnp.array_equal(got, want)


def test_escg_kernel_in_simulation_engine():
    """engine='pallas' must track engine='sublattice' exactly (same keys)."""
    from repro.core import EscgParams, simulate
    kw = dict(length=32, height=16, species=3, mcs=8, tile=(8, 16),
              chunk_mcs=4, empty=0.1, seed=5, mobility=1e-3)
    r1 = simulate(EscgParams(engine="sublattice", **kw), stop_on_stasis=False)
    r2 = simulate(EscgParams(engine="pallas", **kw), stop_on_stasis=False)
    np.testing.assert_array_equal(r1.grid, r2.grid)
    np.testing.assert_allclose(r1.densities, r2.densities, atol=0)


# ------------------------------- density --------------------------------- #

@pytest.mark.parametrize("hw,species", [((8, 16), 3), ((32, 128), 5),
                                        ((17, 33), 8), ((64, 64), 1)])
def test_density_kernel(hw, species):
    grid = init_grid(jax.random.PRNGKey(11), hw[0], hw[1], species, 0.3)
    got = np.asarray(ops.density_counts(grid, species))
    want = np.asarray(ref.density_ref(grid, species))
    np.testing.assert_array_equal(got, want)
    assert got.sum() == hw[0] * hw[1]


# --------------------------- fused-PRNG kernel ---------------------------- #

@pytest.mark.parametrize("hw,tile,species,nbhd,seed", [
    ((32, 64), (8, 16), 5, 4, (0xABCD1234, 0x5678DEAD)),
    ((16, 16), (8, 8), 3, 8, (1, 2)),
    ((24, 48), (8, 16), 8, 4, (0, 0)),
])
def test_escg_fused_kernel_matches_host_philox_oracle(hw, tile, species,
                                                      nbhd, seed):
    """In-kernel Philox proposal derivation == host-side derivation feeding
    the standard tile oracle (bit-exact)."""
    h, w = hw
    th, tw = tile
    grid = init_grid(jax.random.PRNGKey(h + species), h, w, species, 0.1)
    offs = (1, 2) if species >= 5 else (1,)
    dom = jnp.asarray(dm.circulant(species, offs))
    nt = (h // th) * (w // tw)
    k = 61
    seed_arr = jnp.asarray(np.array(seed, np.uint32))
    shift = jnp.array([3, 5], jnp.int32)
    got = ops.escg_round_fused(grid, seed_arr, jnp.uint32(7), shift, dom,
                               tile, k, 0.25, 0.6, nbhd)
    cell, dirn, ua, ud = ref.fused_proposals_ref(
        nt, k, (th - 2) * (tw - 2), nbhd, seed, 7)
    rolled = jnp.roll(grid, (-3, -5), (0, 1))
    want = ref.escg_tile_round_ref(rolled, jnp.asarray(cell),
                                   jnp.asarray(dirn), jnp.asarray(ua),
                                   jnp.asarray(ud), dom, tile, 0.25, 0.6)
    want = jnp.roll(want, (3, 5), (0, 1))
    assert jnp.array_equal(got, want)


@pytest.mark.parametrize("hw,tile,species,nbhd,k_steps", [
    ((16, 32), (8, 16), 5, 4, 3),
    ((16, 16), (8, 8), 3, 8, 4),
])
def test_escg_megakernel_matches_sequential_fused_rounds(hw, tile, species,
                                                         nbhd, k_steps):
    """K grid-resident MCS in ONE pallas_call (escg_rounds_fused) must be
    bit-identical to K single-round fused kernels run back-to-back in the
    drifting frame (roll_back=False), and its in-kernel per-step species
    counts must equal metrics.counts after every step — the k_mcs
    megakernel contract (DESIGN.md §6)."""
    from repro.core import metrics
    h, w = hw
    k = 61
    grid = init_grid(jax.random.PRNGKey(h + species), h, w, species, 0.1)
    offs = (1, 2) if species >= 5 else (1,)
    dom = jnp.asarray(dm.circulant(species, offs))
    rng = np.random.RandomState(7)
    seeds = jnp.asarray(
        rng.randint(0, 2**32, size=(k_steps, 2), dtype=np.uint32))
    shifts = jnp.asarray(np.stack(
        [rng.randint(0, tile[0], k_steps),
         rng.randint(0, tile[1], k_steps)], axis=1).astype(np.int32))
    got_g, got_c = ops.escg_rounds_fused(grid, seeds, shifts, dom, tile, k,
                                         0.25, 0.6, species, nbhd)
    assert got_c.shape == (k_steps, species + 1)
    g = grid
    for t in range(k_steps):
        g = ops.escg_round_fused(g, seeds[t], jnp.uint32(0), shifts[t],
                                 dom, tile, k, 0.25, 0.6, nbhd,
                                 roll_back=False)
        np.testing.assert_array_equal(
            np.asarray(got_c[t]), np.asarray(metrics.counts(g, species)),
            err_msg=f"step {t} counts")
    np.testing.assert_array_equal(np.asarray(got_g), np.asarray(g))


def test_fused_counter_capacity_guard():
    """tile_id * k + j is a uint32 counter: a tiling whose proposal space
    exceeds 2^32 must be rejected loudly, never wrapped silently."""
    from repro.kernels.escg_update_fused import check_counter_capacity
    check_counter_capacity(1 << 16, 1 << 16)          # exactly 2^32: legal
    with pytest.raises(ValueError, match="counter"):
        check_counter_capacity((1 << 16) + 1, 1 << 16)


def test_escg_fused_engine_runs_and_conserves():
    from repro.core import EscgParams, simulate
    p = EscgParams(length=32, height=16, species=4, mcs=10, mu=0.0,
                   sigma=0.0, epsilon=1.0, engine="pallas_fused",
                   tile=(8, 16), chunk_mcs=5, empty=0.25, seed=3)
    r = simulate(p, dm.circulant(4), stop_on_stasis=False)
    np.testing.assert_allclose(r.densities[0], r.densities[-1], atol=1e-9)
