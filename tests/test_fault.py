"""Fault tolerance: restart-from-checkpoint, straggler detection, and the
int8 error-feedback compressor."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import compression
from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.fault import FaultTolerantLoop, StragglerMonitor


def _counter_step(state, batch):
    return {"x": state["x"] + batch}, {"loss": jnp.float32(0.0)}


def test_restart_resumes_from_checkpoint(tmp_path):
    ckpt = CheckpointManager(str(tmp_path), keep=3)
    loop = FaultTolerantLoop(_counter_step, ckpt, ckpt_every=5,
                             max_restarts=2)
    fails = {17}
    state, end = loop.run(
        {"x": jnp.float32(0.0)}, lambda s: jnp.float32(1.0), 20,
        inject_failure=lambda s: s in fails and not fails.discard(s))
    assert end == 20
    assert loop.restarts == 1
    # deterministic step fn + exact restart => same result as failure-free
    assert float(state["x"]) == 20.0


def test_restart_budget_exhausted(tmp_path):
    ckpt = CheckpointManager(str(tmp_path), keep=3)
    loop = FaultTolerantLoop(_counter_step, ckpt, ckpt_every=5,
                             max_restarts=1)
    with pytest.raises(RuntimeError):
        loop.run({"x": jnp.float32(0.0)}, lambda s: jnp.float32(1.0), 20,
                 inject_failure=lambda s: s == 7)


def test_straggler_monitor_flags_outlier():
    mon = StragglerMonitor(k=3.0)
    for _ in range(20):
        mon.record(0.1)
    assert mon.flagged == 0
    assert mon.record(1.0)
    assert mon.flagged == 1


# ----------------------------- compression ------------------------------- #

def test_quantize_roundtrip_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 64)) * 3.0
    q, scale = compression.quantize_int8(x)
    deq = compression.dequantize_int8(q, scale)
    err = np.abs(np.asarray(x) - np.asarray(deq))
    assert err.max() <= float(scale.max()) * 0.5 + 1e-6
    assert q.dtype == jnp.int8


def test_error_feedback_preserves_signal():
    """With EF, the accumulated compressed signal tracks the accumulated
    true gradient (residual stays bounded)."""
    g_true = {"w": jnp.full((8, 8), 0.001)}      # tiny grads: worst case
    ef = {"w": jnp.zeros((8, 8), jnp.bfloat16)}
    total = np.zeros((8, 8))
    for _ in range(50):
        g_c, ef = compression.compress_grads(g_true, ef)
        total += np.asarray(g_c["w"], np.float64)
    want = 50 * 0.001
    np.testing.assert_allclose(total, want, rtol=0.15)
    # WITHOUT error feedback the signal may vanish entirely under coarse
    # quantization; with EF the residual is bounded by one quant step
    assert np.abs(np.asarray(ef["w"], np.float64)).max() < 0.01


def test_compress_grads_tree_structure():
    grads = {"a": {"w": jnp.ones((4, 4))}, "b": jnp.ones((3,))}
    ef = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.bfloat16), grads)
    out, new_ef = compression.compress_grads(grads, ef)
    assert jax.tree.structure(out) == jax.tree.structure(grads)
    assert jax.tree.structure(new_ef) == jax.tree.structure(grads)
    np.testing.assert_allclose(np.asarray(out["a"]["w"]), 1.0, rtol=0.02)


def test_train_step_ef_state_persists_across_steps():
    """EF residuals must live in the jitted train state (a python-closure
    compressor would freeze them at trace time)."""
    import jax
    from repro.configs import ARCHS
    from repro.configs.base import ShapeConfig
    from repro.models import build_model
    from repro.runtime import train_lib

    model = build_model(ARCHS["yi-9b"].reduced())
    state = train_lib.init_state(model, jax.random.PRNGKey(0),
                                 compress=True)
    assert "ef" in state
    step = jax.jit(train_lib.make_train_step(model, compress=True))
    batch = model.concrete_inputs(ShapeConfig("t", 32, 2, "train"),
                                  jax.random.PRNGKey(1))
    s1, _ = step(state, batch)
    s2, _ = step(s1, batch)
    ef1 = np.abs(np.asarray(jax.tree.leaves(s1["ef"])[0],
                            np.float32)).sum()
    ef2 = np.abs(np.asarray(jax.tree.leaves(s2["ef"])[0],
                            np.float32)).sum()
    assert ef1 > 0.0          # residuals actually accumulate
    assert ef1 != ef2         # and evolve across steps
