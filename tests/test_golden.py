"""Golden regression fixtures: frozen trajectories under tests/golden/.

The bit-identity guarantees in this repo (DESIGN.md §3) are all *relative*
— engine A equals engine B, layout X equals layout Y. A change that shifts
EVERY engine's PRNG consumption or update order in lockstep (e.g. an extra
key split in the driver, a reordered proposal field) would sail through
those tests. The goldens pin the *absolute* trajectories: a tiny
``reference``-engine run (per-MCS grid hashes + densities), a
``sublattice``-family ``TrialResult``, and a ``pallas_fused`` run (the
second oracle family — its in-kernel Philox counter layout anchors every
``local_kernel='fused'`` path; a lockstep change to the counter mapping
would pass the relative fused-vs-sharded tests and fail only here), all
checked in as JSON. Any drift in PRNG streams, update order, or the
streamed statistics pipeline fails here, even on single-device CI.

Regenerate (ONLY when a change intentionally redefines trajectories):

    PYTHONPATH=src python tests/test_golden.py --regen
"""
import hashlib
import json
import os

import numpy as np
import pytest

from repro.core import EscgParams, dominance as dm, simulate
from repro.core.trials import run_trials

pytestmark = pytest.mark.composed   # re-run by the CI 8-fake-device job

GOLDEN_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          "golden")
TRAJ_PATH = os.path.join(GOLDEN_DIR, "reference_trajectory.json")
TRIALS_PATH = os.path.join(GOLDEN_DIR, "trial_result.json")
FUSED_PATH = os.path.join(GOLDEN_DIR, "fused_trajectory.json")

# frozen configs — changing these invalidates the fixtures, regenerate
TRAJ_PARAMS = EscgParams(length=12, height=12, species=3, mcs=5,
                         chunk_mcs=1, engine="reference", mobility=1e-3,
                         empty=0.1, seed=42)
TRIAL_PARAMS = EscgParams(length=16, height=16, species=5, mobility=1e-3,
                          engine="sublattice", tile=(8, 8), empty=0.1,
                          seed=7)
TRIAL_N, TRIAL_MCS, TRIAL_CHUNK = 4, 6, 3
FUSED_PARAMS = EscgParams(length=16, height=16, species=5, mcs=5,
                          chunk_mcs=1, engine="pallas_fused", tile=(8, 8),
                          mobility=1e-3, empty=0.1, seed=11)


def _grid_hash(grid: np.ndarray) -> str:
    """Platform-stable lattice digest: little-endian int32 raster bytes."""
    return hashlib.sha256(
        np.ascontiguousarray(grid.astype("<i4")).tobytes()).hexdigest()


def _capture_trajectory(params, dom):
    """Frozen-trajectory record for one (params, dominance) config:
    per-MCS grid hashes via hooks, densities/final grid from the same
    run (simulate is deterministic; one execution serves both)."""
    hashes = []
    res = simulate(params, dom, stop_on_stasis=False,
                   hooks=[lambda mcs, grid, cnts:
                          hashes.append(_grid_hash(np.asarray(grid)))])
    return {
        "params": json.loads(params.to_json()),
        "grid_hashes": hashes,                       # one per MCS
        "densities": np.asarray(res.densities).tolist(),  # row 0 = init
        "final_hash": _grid_hash(res.grid),
        "kept_fraction": res.kept_fraction,
    }


def _run_trajectory():
    return _capture_trajectory(TRAJ_PARAMS, dm.RPS())


def _run_trials_golden() -> str:
    return run_trials(TRIAL_PARAMS, dm.RPSLS(), TRIAL_N, n_mcs=TRIAL_MCS,
                      chunk_mcs=TRIAL_CHUNK, stop_on_stasis=False).to_json()


def _run_fused_trajectory():
    return _capture_trajectory(FUSED_PARAMS, dm.RPSLS())


def test_reference_trajectory_matches_golden():
    with open(TRAJ_PATH) as f:
        want = json.load(f)
    got = _run_trajectory()
    assert got["grid_hashes"] == want["grid_hashes"], (
        "reference-engine trajectory drifted from tests/golden/ — PRNG "
        "stream or update order changed; regenerate only if intentional")
    assert got["final_hash"] == want["final_hash"]
    np.testing.assert_array_equal(np.asarray(got["densities"]),
                                  np.asarray(want["densities"]))
    assert got["kept_fraction"] == want["kept_fraction"]
    assert got["params"] == want["params"]


def test_trial_result_matches_golden():
    with open(TRIALS_PATH) as f:
        want = json.load(f)
    got = json.loads(_run_trials_golden())
    # n_devices legitimately varies with the host (pod width); everything
    # else — survival, densities, stasis/extinction MCS, kept — must not
    want.pop("n_devices"), got.pop("n_devices")
    assert got == want, (
        "TrialResult drifted from tests/golden/ — trial keying, streamed "
        "statistics, or update order changed; regenerate only if "
        "intentional")


def test_fused_trajectory_matches_golden():
    """Absolute anchor of the fused-Philox family: the in-kernel counter
    layout (global tile id * K + j, round index, seed words) must not
    drift — every sharded ``local_kernel='fused'`` path inherits this
    trajectory through the ``pallas_fused`` oracle."""
    with open(FUSED_PATH) as f:
        want = json.load(f)
    got = _run_fused_trajectory()
    assert got["grid_hashes"] == want["grid_hashes"], (
        "pallas_fused trajectory drifted from tests/golden/ — the fused "
        "Philox counter layout or update order changed; regenerate only "
        "if intentional")
    assert got["final_hash"] == want["final_hash"]
    np.testing.assert_array_equal(np.asarray(got["densities"]),
                                  np.asarray(want["densities"]))
    assert got["kept_fraction"] == want["kept_fraction"]
    assert got["params"] == want["params"]


def test_goldens_are_checked_in():
    """The fixtures must live in git, not be produced on the fly."""
    for path in (TRAJ_PATH, TRIALS_PATH, FUSED_PATH):
        assert os.path.exists(path), (
            f"{path} missing — run: PYTHONPATH=src python "
            "tests/test_golden.py --regen")


def _regen():
    os.makedirs(GOLDEN_DIR, exist_ok=True)
    with open(TRAJ_PATH, "w") as f:
        json.dump(_run_trajectory(), f, indent=1)
    with open(TRIALS_PATH, "w") as f:
        f.write(_run_trials_golden())
    with open(FUSED_PATH, "w") as f:
        json.dump(_run_fused_trajectory(), f, indent=1)
    print(f"regenerated {TRAJ_PATH}, {TRIALS_PATH} and {FUSED_PATH}")


if __name__ == "__main__":
    import sys
    if "--regen" in sys.argv:
        _regen()
    else:
        print(__doc__)
