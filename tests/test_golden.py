"""Golden regression fixtures: frozen trajectories under tests/golden/.

The bit-identity guarantees in this repo (DESIGN.md §3) are all *relative*
— engine A equals engine B, layout X equals layout Y. A change that shifts
EVERY engine's PRNG consumption or update order in lockstep (e.g. an extra
key split in the driver, a reordered proposal field) would sail through
those tests. The goldens pin the *absolute* trajectories: a tiny
``reference``-engine run (per-MCS grid hashes + densities) and a
``sublattice``-family ``TrialResult``, checked in as JSON. Any drift in
PRNG streams, update order, or the streamed statistics pipeline fails
here, even on single-device CI.

Regenerate (ONLY when a change intentionally redefines trajectories):

    PYTHONPATH=src python tests/test_golden.py --regen
"""
import hashlib
import json
import os

import numpy as np

from repro.core import EscgParams, dominance as dm, simulate
from repro.core.trials import run_trials

GOLDEN_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          "golden")
TRAJ_PATH = os.path.join(GOLDEN_DIR, "reference_trajectory.json")
TRIALS_PATH = os.path.join(GOLDEN_DIR, "trial_result.json")

# frozen configs — changing these invalidates the fixtures, regenerate
TRAJ_PARAMS = EscgParams(length=12, height=12, species=3, mcs=5,
                         chunk_mcs=1, engine="reference", mobility=1e-3,
                         empty=0.1, seed=42)
TRIAL_PARAMS = EscgParams(length=16, height=16, species=5, mobility=1e-3,
                          engine="sublattice", tile=(8, 8), empty=0.1,
                          seed=7)
TRIAL_N, TRIAL_MCS, TRIAL_CHUNK = 4, 6, 3


def _grid_hash(grid: np.ndarray) -> str:
    """Platform-stable lattice digest: little-endian int32 raster bytes."""
    return hashlib.sha256(
        np.ascontiguousarray(grid.astype("<i4")).tobytes()).hexdigest()


def _run_trajectory():
    hashes = []
    simulate(TRAJ_PARAMS, dm.RPS(), stop_on_stasis=False,
             hooks=[lambda mcs, grid, cnts:
                    hashes.append(_grid_hash(np.asarray(grid)))])
    res = simulate(TRAJ_PARAMS, dm.RPS(), stop_on_stasis=False)
    return {
        "params": json.loads(TRAJ_PARAMS.to_json()),
        "grid_hashes": hashes,                       # one per MCS
        "densities": np.asarray(res.densities).tolist(),  # row 0 = init
        "final_hash": _grid_hash(res.grid),
        "kept_fraction": res.kept_fraction,
    }


def _run_trials_golden() -> str:
    return run_trials(TRIAL_PARAMS, dm.RPSLS(), TRIAL_N, n_mcs=TRIAL_MCS,
                      chunk_mcs=TRIAL_CHUNK, stop_on_stasis=False).to_json()


def test_reference_trajectory_matches_golden():
    with open(TRAJ_PATH) as f:
        want = json.load(f)
    got = _run_trajectory()
    assert got["grid_hashes"] == want["grid_hashes"], (
        "reference-engine trajectory drifted from tests/golden/ — PRNG "
        "stream or update order changed; regenerate only if intentional")
    assert got["final_hash"] == want["final_hash"]
    np.testing.assert_array_equal(np.asarray(got["densities"]),
                                  np.asarray(want["densities"]))
    assert got["kept_fraction"] == want["kept_fraction"]
    assert got["params"] == want["params"]


def test_trial_result_matches_golden():
    with open(TRIALS_PATH) as f:
        want = json.load(f)
    got = json.loads(_run_trials_golden())
    # n_devices legitimately varies with the host (pod width); everything
    # else — survival, densities, stasis/extinction MCS, kept — must not
    want.pop("n_devices"), got.pop("n_devices")
    assert got == want, (
        "TrialResult drifted from tests/golden/ — trial keying, streamed "
        "statistics, or update order changed; regenerate only if "
        "intentional")


def test_goldens_are_checked_in():
    """The fixtures must live in git, not be produced on the fly."""
    for path in (TRAJ_PATH, TRIALS_PATH):
        assert os.path.exists(path), (
            f"{path} missing — run: PYTHONPATH=src python "
            "tests/test_golden.py --regen")


def _regen():
    os.makedirs(GOLDEN_DIR, exist_ok=True)
    with open(TRAJ_PATH, "w") as f:
        json.dump(_run_trajectory(), f, indent=1)
    with open(TRIALS_PATH, "w") as f:
        f.write(_run_trials_golden())
    print(f"regenerated {TRAJ_PATH} and {TRIALS_PATH}")


if __name__ == "__main__":
    import sys
    if "--regen" in sys.argv:
        _regen()
    else:
        print(__doc__)
