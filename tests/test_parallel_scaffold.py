"""Multi-device LM-scaffold correctness (DESIGN.md §9): dry-run lowering,
elastic resharding, pipeline parallelism. Moved out of the former
tests/test_sharded.py — the ESCG sharded-engine tests live in
tests/test_sharded_engine.py. Subprocesses set fake device counts so unit
tests keep seeing the single real CPU device."""
import pytest


@pytest.mark.slow
def test_mini_dryrun_lowers_on_fake_mesh(subproc):
    """End-to-end pjit lowering on a small fake mesh for one dense and the
    hybrid arch (the full 512-device sweep runs via launch/dryrun)."""
    out = subproc("""
        import jax, jax.numpy as jnp
        from repro.configs import get_arch
        from repro.configs.base import ShapeConfig
        from repro.launch.dryrun import _compile_cell
        from repro.launch.mesh import make_mesh
        from repro.parallel.sharding import make_rules

        mesh = make_mesh((2, 2), ("data", "model"))
        for arch in ("granite-3-8b", "zamba2-7b"):
            cfg = get_arch(arch).reduced().replace(
                n_layers=4, scan_layers=True, attn_every=2)
            shape = ShapeConfig("t", 64, 4, "train")
            rules = make_rules(mesh, {}, "train", 4)
            compiled, _ = _compile_cell(cfg, shape, mesh, rules)
            assert compiled.cost_analysis() is not None
            print("LOWERED", arch)
    """, n_devices=4)
    assert out.count("LOWERED") == 2


@pytest.mark.slow
def test_elastic_reshard(subproc):
    """Checkpoint on an 8-device mesh, restore onto a 2-device layout —
    elastic scaling path (DESIGN.md §5)."""
    out = subproc("""
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_mesh
        from repro.runtime.checkpoint import CheckpointManager
        from repro.runtime.fault import elastic_restore

        d = tempfile.mkdtemp()
        mesh8 = make_mesh((4, 2), ("data", "model"))
        x = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
        xs = jax.device_put(x, NamedSharding(mesh8, P("data", "model")))
        cm = CheckpointManager(d)
        cm.save(3, {"w": xs})

        mesh2 = make_mesh((2,), ("data",))
        sh = {"w": NamedSharding(mesh2, P("data"))}
        step, got = elastic_restore(cm, sh)
        assert step == 3
        np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(x))
        assert len(got["w"].sharding.device_set) == 2
        print("RESHARDED")
    """, n_devices=8)
    assert "RESHARDED" in out


@pytest.mark.slow
def test_pipeline_matches_sequential(subproc):
    """GPipe pipeline over 4 stages == sequential layer composition."""
    out = subproc("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_mesh
        from repro.parallel.pipeline import pipeline_apply

        mesh = make_mesh((4,), ("stage",))
        k = jax.random.PRNGKey(0)
        stages, d = 4, 16
        w1 = jax.random.normal(k, (stages, d, 32)) * 0.1
        w2 = jax.random.normal(jax.random.fold_in(k, 1),
                               (stages, 32, d)) * 0.1
        params = {"w1": w1, "w2": w2}
        x = jax.random.normal(jax.random.fold_in(k, 2), (8, d))

        def block(p, h):
            return h + jax.nn.gelu(h @ p["w1"]) @ p["w2"]

        want = x
        for i in range(stages):
            want = block({"w1": w1[i], "w2": w2[i]}, want)

        got = pipeline_apply(block, params, x, n_micro=4, mesh=mesh)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5, rtol=1e-5)
        # also exercise a bubble-heavy config (n_micro == 1)
        got1 = pipeline_apply(block, params, x, n_micro=1, mesh=mesh)
        np.testing.assert_allclose(np.asarray(got1), np.asarray(want),
                                   atol=1e-5, rtol=1e-5)
        print("PIPELINE_OK")
    """, n_devices=4)
    assert "PIPELINE_OK" in out
