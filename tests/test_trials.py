"""The device-sharded trial subsystem (core/trials.py, DESIGN.md §4).

Fast tests run on the single real CPU device; the device-layout
bit-identity acceptance test spawns a subprocess with fake CPU devices
(slow, nightly CI)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EscgParams, dominance as dm
from repro.core.trials import (TrialResult, build_trial_chunk, pad_trials,
                               pod_sharding, run_trials,
                               trial_grids_and_keys)

pytestmark = pytest.mark.composed   # re-run by the CI 8-fake-device job


def small_params(**kw):
    base = dict(length=12, height=12, species=3, seed=9)
    base.update(kw)
    return EscgParams(**base)


# ------------------------------- driver ----------------------------------- #

def test_run_trials_returns_trial_result():
    r = run_trials(small_params(), dm.RPS(), n_trials=5, n_mcs=10)
    assert isinstance(r, TrialResult)
    assert r.survival.shape == (5, 3) and r.survival.dtype == bool
    assert r.densities.shape == (5, 4)
    np.testing.assert_allclose(r.densities.sum(axis=1), 1.0, atol=1e-6)
    assert r.stasis_mcs.shape == (5,)
    assert r.extinction_mcs.shape == (5, 3)
    assert r.mcs_completed == 10
    assert r.n_trials == 5 and r.n_devices >= 1
    # 10 MCS on a 12x12 RPS grid: everyone still alive, nothing extinct
    assert r.survival.all()
    assert (r.extinction_mcs == -1).all()
    assert 0.0 < r.kept_fraction <= 1.0


def test_trial_prefix_stability():
    """fold-in keys: trial t's trajectory is independent of the batch size,
    so a prefix of a larger batch equals the smaller batch (this is also
    what makes padding sound)."""
    p = small_params(species=5, mobility=1e-4)
    dom = dm.RPSLS()
    r5 = run_trials(p, dom, n_trials=5, n_mcs=8, stop_on_stasis=False)
    r3 = run_trials(p, dom, n_trials=3, n_mcs=8, stop_on_stasis=False)
    np.testing.assert_array_equal(r3.survival, r5.survival[:3])
    np.testing.assert_array_equal(r3.densities, r5.densities[:3])
    np.testing.assert_array_equal(r3.stasis_mcs, r5.stasis_mcs[:3])
    np.testing.assert_array_equal(r3.extinction_mcs, r5.extinction_mcs[:3])


def test_chunking_invariance():
    """Statistics are independent of the chunk split (the per-MCS key
    threading never sees chunk boundaries)."""
    p = small_params(species=5, mobility=1e-4)
    dom = dm.RPSLS()
    r_mono = run_trials(p, dom, 4, n_mcs=9, chunk_mcs=9,
                        stop_on_stasis=False)
    r_chunk = run_trials(p, dom, 4, n_mcs=9, chunk_mcs=2,
                         stop_on_stasis=False)
    np.testing.assert_array_equal(r_mono.survival, r_chunk.survival)
    np.testing.assert_array_equal(r_mono.densities, r_chunk.densities)
    np.testing.assert_array_equal(r_mono.stasis_mcs, r_chunk.stasis_mcs)
    np.testing.assert_array_equal(r_mono.extinction_mcs,
                                  r_chunk.extinction_mcs)


def test_stasis_early_exit_and_recording():
    """Single species + empties: stasis from MCS 1; the driver exits at the
    first chunk boundary instead of running all 500 MCS."""
    p = EscgParams(length=10, height=10, species=1, mcs=500, chunk_mcs=50,
                   empty=0.5, mu=0.0, sigma=1.0, epsilon=0.0, seed=0)
    r = run_trials(p, np.zeros((1, 1), np.float32), n_trials=3)
    assert (r.stasis_mcs == 1).all()
    assert r.mcs_completed == 50          # one chunk, then the early exit


def test_async_stats_schedule_invariance():
    """async_stats keeps one speculative chunk in flight while the host
    folds statistics; the schedule must not leak into ANY result field —
    including mcs_completed at a stasis early-exit, where the in-flight
    chunk is dropped unconsumed."""
    p = small_params(species=5, mobility=1e-4)
    dom = dm.RPSLS()
    a = run_trials(p, dom, 4, n_mcs=9, chunk_mcs=2, stop_on_stasis=False,
                   async_stats=True)
    b = run_trials(p, dom, 4, n_mcs=9, chunk_mcs=2, stop_on_stasis=False,
                   async_stats=False)
    np.testing.assert_array_equal(a.survival, b.survival)
    np.testing.assert_array_equal(a.densities, b.densities)
    np.testing.assert_array_equal(a.stasis_mcs, b.stasis_mcs)
    np.testing.assert_array_equal(a.extinction_mcs, b.extinction_mcs)
    assert a.mcs_completed == b.mcs_completed == 9

    pe = EscgParams(length=10, height=10, species=1, mcs=500, chunk_mcs=50,
                    empty=0.5, mu=0.0, sigma=1.0, epsilon=0.0, seed=0)
    dom1 = np.zeros((1, 1), np.float32)
    for async_stats in (True, False):
        r = run_trials(pe, dom1, n_trials=3, async_stats=async_stats)
        assert r.mcs_completed == 50, async_stats


def test_async_early_exit_drops_speculative_chunk_with_live_dynamics():
    """The sharp edge of the speculative schedule: single species with
    empties reaches stasis at MCS 1 (alive <= 1 forever) while the
    densities KEEP evolving as rare reproduction events fill empties
    (migration-dominated rates keep the fill slow enough not to saturate)
    — so the chunk the async driver has in flight at the early exit
    carries genuinely different statistics. Folding it in would change
    densities and mcs_completed; every field must match the synchronous
    schedule exactly."""
    p = EscgParams(length=12, height=12, species=1, mcs=40, chunk_mcs=4,
                   empty=0.6, mu=0.0, sigma=0.02, epsilon=1.0, seed=2)
    dom = np.zeros((1, 1), np.float32)
    sync = run_trials(p, dom, n_trials=3, async_stats=False)
    # the dynamics are really live past the exit point: four more MCS of
    # the same run change the density stream, so the dropped speculative
    # chunk WOULD have perturbed the stats had it been folded in
    longer = run_trials(p.replace(chunk_mcs=8), dom, n_trials=3,
                        async_stats=False)
    assert not np.array_equal(longer.densities, sync.densities)
    assert longer.mcs_completed == 8

    r = run_trials(p, dom, n_trials=3, async_stats=True)
    assert r.mcs_completed == sync.mcs_completed == 4
    np.testing.assert_array_equal(r.survival, sync.survival)
    np.testing.assert_array_equal(r.densities, sync.densities)
    np.testing.assert_array_equal(r.stasis_mcs, sync.stasis_mcs)
    np.testing.assert_array_equal(r.extinction_mcs, sync.extinction_mcs)
    assert (r.stasis_mcs == 1).all()


def test_cell_dtype_honoured_and_value_stable():
    """The trial driver honours params.cell_dtype (the legacy vmap runner
    dropped it), and the dtype does not change trajectories."""
    p8 = small_params(cell_dtype="int8")
    grids, _ = trial_grids_and_keys(p8.validate(), jax.random.PRNGKey(0), 2)
    assert grids.dtype == jnp.int8
    r8 = run_trials(p8, dm.RPS(), 3, n_mcs=6, stop_on_stasis=False)
    r32 = run_trials(small_params(cell_dtype="int32"), dm.RPS(), 3, n_mcs=6,
                     stop_on_stasis=False)
    np.testing.assert_array_equal(r8.survival, r32.survival)
    np.testing.assert_array_equal(r8.densities, r32.densities)


def test_zero_mcs_returns_initial_state():
    """n_mcs=0 (Park Table 4.2 has MCS=0 cells): no chunks run and the
    result carries the initial survival mask, like the legacy runner."""
    r = run_trials(small_params(empty=0.0), dm.RPS(), 3, n_mcs=0)
    assert r.mcs_completed == 0
    assert r.survival.all()
    np.testing.assert_allclose(r.densities.sum(axis=1), 1.0, atol=1e-6)
    assert r.kept_fraction == 1.0
    with pytest.raises(ValueError, match="chunk_mcs"):
        run_trials(small_params(), dm.RPS(), 3, n_mcs=5, chunk_mcs=0)


def test_padding_helper():
    assert pad_trials(5, 4) == 8
    assert pad_trials(8, 4) == 8
    assert pad_trials(1, 4) == 4
    assert pad_trials(7, 1) == 7


def test_pod_sharding_validation():
    with pytest.raises(ValueError, match="trial_devices"):
        pod_sharding(0)
    with pytest.raises(ValueError, match="local devices"):
        pod_sharding(10_000)


def test_rejects_non_vmappable_engine():
    with pytest.raises(ValueError, match="vmappable"):
        run_trials(EscgParams(length=16, height=16, engine="sharded",
                              tile=(8, 8)), dm.RPS(), n_trials=2, n_mcs=1)


def test_hooks_stream_per_chunk():
    calls = []
    run_trials(small_params(), dm.RPS(), 4, n_mcs=9, chunk_mcs=3,
               stop_on_stasis=False,
               hooks=[lambda m, alive: calls.append((m, alive.shape))])
    assert [c[0] for c in calls] == [3, 6, 9]
    assert all(c[1] == (4,) for c in calls)


def test_trial_chunk_shapes():
    p = small_params().validate()
    dom = jnp.asarray(dm.RPS(), jnp.float32)
    grids, keys = trial_grids_and_keys(p, jax.random.PRNGKey(1), 4)
    chunk = build_trial_chunk(p, dom)
    g2, k2, cnts, alive, kept, att = chunk(grids, keys, 5)
    assert g2.shape == (4, 12, 12)
    assert cnts.shape == (4, 4)
    assert alive.shape == (4, 5, 3) and alive.dtype == jnp.bool_
    assert kept.shape == (4,) and att.shape == (4,)
    assert int(cnts.sum()) == 4 * p.n_cells


# ----------------------- TrialResult statistics --------------------------- #

def test_trial_result_statistics_roundtrip():
    surv = np.array([[True, True, False],
                     [True, False, False],
                     [True, True, True],
                     [True, False, False]])
    res = TrialResult(
        survival=surv,
        densities=np.array([[0.0, 0.5, 0.5, 0.0]] * 4),
        stasis_mcs=np.array([3, -1, 7, 2]),
        extinction_mcs=np.array([[-1, -1, 4]] * 4),
        mcs_completed=10, kept_fraction=0.9, n_trials=4, n_devices=2)

    np.testing.assert_allclose(res.survival_probabilities(),
                               [1.0, 0.5, 0.25])
    hist = res.survivors_hist()
    assert hist.shape == (4,)
    np.testing.assert_allclose(hist, [0.0, 0.5, 0.25, 0.25])
    assert abs(hist.sum() - 1.0) < 1e-9
    assert res.extinction_probability(1) == 0.0
    assert res.extinction_probability(3) == 0.75
    assert res.species == 3

    back = TrialResult.from_json(res.to_json())
    np.testing.assert_array_equal(back.survival, res.survival)
    np.testing.assert_allclose(back.densities, res.densities)
    np.testing.assert_array_equal(back.stasis_mcs, res.stasis_mcs)
    np.testing.assert_array_equal(back.extinction_mcs, res.extinction_mcs)
    assert back.mcs_completed == res.mcs_completed
    assert back.kept_fraction == res.kept_fraction
    assert back.n_trials == res.n_trials
    assert back.n_devices == res.n_devices
    assert back.survival.dtype == bool


def test_legacy_wrapper_returns_survival_mask():
    from repro.core import run_trials as legacy
    surv = legacy(small_params(), dm.RPS(), 5, n_mcs=10)
    assert isinstance(surv, np.ndarray)
    assert surv.shape == (5, 3) and surv.dtype == bool


# ------------------------------ multi-device ------------------------------- #

@pytest.mark.slow
def test_trials_bit_identical_across_device_layouts(subproc):
    """Acceptance: the sharded trial runner is bit-identical to the
    single-device vmap path for pod widths 1/2/4, including a trial count
    that does not divide the device count (6 pads to 8 on 4 devices)."""
    out = subproc("""
        import numpy as np
        from repro.core import EscgParams, dominance as dm
        from repro.core.trials import run_trials
        p = EscgParams(length=16, height=16, species=5, mobility=1e-4,
                       seed=3, cell_dtype='int8')
        dom = dm.RPSLS()
        rs = {d: run_trials(p, dom, n_trials=6, n_mcs=8, trial_devices=d,
                            chunk_mcs=3, stop_on_stasis=False)
              for d in (1, 2, 4)}
        base = rs[1]
        for d in (2, 4):
            r = rs[d]
            assert r.n_devices == d
            assert np.array_equal(r.survival, base.survival), d
            assert np.array_equal(r.densities, base.densities), d
            assert np.array_equal(r.stasis_mcs, base.stasis_mcs), d
            assert np.array_equal(r.extinction_mcs,
                                  base.extinction_mcs), d
        print("POD_BIT_IDENTICAL")
    """, n_devices=4)
    assert "POD_BIT_IDENTICAL" in out


@pytest.mark.slow
def test_trials_default_pod_width_uses_all_devices(subproc):
    """trial_devices=None shards over every local device and still matches
    the explicit single-device run."""
    out = subproc("""
        import numpy as np
        from repro.core import EscgParams, dominance as dm
        from repro.core.trials import run_trials
        p = EscgParams(length=12, height=12, species=3, seed=0)
        r_all = run_trials(p, dm.RPS(), 5, n_mcs=4, stop_on_stasis=False)
        r_one = run_trials(p, dm.RPS(), 5, n_mcs=4, trial_devices=1,
                           stop_on_stasis=False)
        assert r_all.n_devices == 4
        assert np.array_equal(r_all.survival, r_one.survival)
        assert np.array_equal(r_all.densities, r_one.densities)
        print("POD_DEFAULT_OK")
    """, n_devices=4)
    assert "POD_DEFAULT_OK" in out
