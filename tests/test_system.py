"""End-to-end CLI behaviour tests for the shipped drivers."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_cli(args, timeout=420):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-m"] + args, capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, out.stdout + out.stderr
    return out.stdout


@pytest.mark.slow
def test_escg_cli_save_and_resume(tmp_path):
    out_dir = str(tmp_path / "run")
    out = run_cli(["repro.launch.escg_run", "--length", "32", "--height",
                   "32", "--mcs", "40", "--engine", "batched", "--save",
                   "true", "--outDir", out_dir, "--chunkMcs", "20",
                   "--empty", "0.1"])
    assert "40 MCS" in out
    assert os.path.exists(os.path.join(out_dir, "grid.csv"))
    assert os.path.exists(os.path.join(out_dir, "densities.csv"))
    out2 = run_cli(["repro.launch.escg_run", "--resume", "true", "--mcs",
                    "60", "--outDir", out_dir])
    assert "resumed" in out2 and "20 MCS" in out2


@pytest.mark.slow
def test_escg_cli_dominance_import(tmp_path):
    from repro.core import dominance as dm
    csv = tmp_path / "dom.csv"
    csv.write_text(dm.to_csv(dm.zhong_ablated_rpsls()))
    out = run_cli(["repro.launch.escg_run", "--length", "24", "--height",
                   "24", "--mcs", "10", "--dominance", str(csv),
                   "--engine", "reference", "--chunkMcs", "10"])
    assert "species=5" in out


@pytest.mark.slow
def test_train_cli_smoke(tmp_path):
    out = run_cli(["repro.launch.train", "--arch", "minitron-4b",
                   "--reduced", "--steps", "6", "--batch", "2", "--seq",
                   "64", "--ckpt_dir", str(tmp_path / "ck"),
                   "--ckpt_every", "3", "--log_every", "2"])
    assert "done: steps 0->6" in out
    # checkpoint written and resumable
    out2 = run_cli(["repro.launch.train", "--arch", "minitron-4b",
                    "--reduced", "--steps", "8", "--batch", "2", "--seq",
                    "64", "--ckpt_dir", str(tmp_path / "ck"), "--resume",
                    "--log_every", "2"])
    assert "resumed from step 6" in out2


@pytest.mark.slow
def test_train_cli_with_compression(tmp_path):
    out = run_cli(["repro.launch.train", "--arch", "yi-9b", "--reduced",
                   "--steps", "4", "--batch", "2", "--seq", "32",
                   "--ckpt_dir", str(tmp_path / "ck"), "--compress"])
    assert "done" in out


@pytest.mark.slow
def test_serve_cli_smoke(tmp_path):
    """The escg_serve entry point end-to-end: synthetic trace, two waves,
    acceptance checks (zero dropped / zero errors / >= 1 cache hit)."""
    report = str(tmp_path / "report.json")
    out = run_cli(["repro.launch.serve", "--synthetic", "2", "--waves",
                   "2", "--report", report, "--check"])
    assert "req/s" in out and "dropped=0" in out
    with open(report) as f:
        rep = json.load(f)
    assert rep["schema"] == "escg-serve-report/v1"
    assert rep["n_requests"] == 4 and rep["n_error"] == 0
    assert rep["cache"]["hits"] >= 1
