"""Docs stay wired to the code: the README engine matrix is generated from
the live registry (and CI-checked), DESIGN.md sections cited by docstrings
exist, and benchmarks/README.md covers every benchmark module."""
import glob
import os
import re

from repro.launch.escg_run import (engine_matrix_markdown,
                                   readme_matrix_drift,
                                   readme_scenario_drift,
                                   scenario_matrix_markdown)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_readme_engine_matrix_matches_registry():
    drift = readme_matrix_drift(os.path.join(REPO, "README.md"))
    assert drift is None, drift


def test_engine_matrix_lists_every_engine():
    from repro.core import engines
    md = engine_matrix_markdown()
    for name in engines.engine_names():
        assert f"`{name}`" in md, name


def test_readme_scenario_matrix_matches_registry():
    drift = readme_scenario_drift(os.path.join(REPO, "README.md"))
    assert drift is None, drift


def test_scenario_matrix_lists_every_scenario():
    from repro.core import scenarios
    md = scenario_matrix_markdown()
    for name in scenarios.scenario_names():
        assert f"`{name}`" in md, name


def test_design_md_has_every_cited_section():
    """Every ``DESIGN.md §N`` reference in src/ and tests/ must resolve to
    a ``## §N`` heading in docs/DESIGN.md — no dangling citations."""
    with open(os.path.join(REPO, "docs", "DESIGN.md")) as f:
        design = f.read()
    sections = set(re.findall(r"^## (§\d+)", design, re.M))
    assert sections, "docs/DESIGN.md has no §-numbered sections"

    cited = set()
    for root in ("src", "tests", "benchmarks"):
        for path in glob.glob(os.path.join(REPO, root, "**", "*.py"),
                              recursive=True):
            with open(path) as f:
                for ref in re.findall(r"DESIGN\.md (§\d+)", f.read()):
                    cited.add((os.path.relpath(path, REPO), ref))
    assert cited, "expected DESIGN.md citations in the codebase"
    dangling = [(p, ref) for p, ref in cited if ref not in sections]
    assert not dangling, f"dangling DESIGN.md refs: {dangling}"


def test_benchmarks_readme_covers_every_module():
    with open(os.path.join(REPO, "benchmarks", "README.md")) as f:
        text = f.read()
    mods = [os.path.basename(p)
            for p in glob.glob(os.path.join(REPO, "benchmarks", "*.py"))
            if os.path.basename(p) not in ("run.py", "common.py",
                                           "__init__.py")]
    assert mods
    missing = [m for m in mods if m not in text]
    assert not missing, f"benchmarks/README.md misses: {missing}"


def test_ci_checks_readme_matrix():
    with open(os.path.join(REPO, ".github", "workflows", "ci.yml")) as f:
        ci = f.read().replace("\n          ", " ")
    assert "--listEngines --check README.md" in ci
    assert "--listScenarios --check README.md" in ci
