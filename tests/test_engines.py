"""Engine equivalence: the parallel engines against the sequential oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # hermetic container: deterministic fallback sampler
    from _propcheck import given, settings, strategies as st

from repro.core import batched, dominance as dm, reference
from repro.core.lattice import init_grid
from repro.core.rng import ProposalBatch, proposal_batch, tile_proposal_batch
from repro.core.sublattice import run_round, tile_update


@given(seed=st.integers(0, 10_000), species=st.integers(1, 6),
       nbhd=st.sampled_from([4, 8]), flux=st.booleans(),
       b=st.integers(1, 200))
@settings(max_examples=25, deadline=None)
def test_batched_equals_sequential_drop(seed, species, nbhd, flux, b):
    """E2 (scatter-min arbitration) is bit-identical to the sequential
    engine that drops conflicting proposals — for ANY config."""
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    grid = init_grid(k1, 12, 20, species, 0.2)
    dom = jnp.asarray(dm.circulant(species) if species > 1
                      else dm.from_dense(np.zeros((1, 1))))
    batch = proposal_batch(k2, b, 12 * 20, nbhd)
    te, tem = 0.25, 0.65
    g_ref, k_ref = reference.run_proposals(grid, batch, te, tem, dom, flux,
                                           drop_conflicts=True)
    g_bat, k_bat = batched.run_proposals(grid, batch, te, tem, dom, flux)
    assert jnp.array_equal(g_ref, g_bat)
    assert int(k_ref) == int(k_bat)


def test_batched_conflict_free_equals_paper_sequential():
    """With disjoint proposals, drop/no-drop semantics coincide: E2 equals
    the exact paper Algorithm 3.2 sequence."""
    key = jax.random.PRNGKey(3)
    grid = init_grid(key, 16, 16, 3, 0.1)
    dom = jnp.asarray(dm.RPS())
    # hand-build disjoint proposals: cells spaced 4 apart, neighbour right
    cells = jnp.arange(0, 256, 4, dtype=jnp.int32)
    b = cells.shape[0]
    batch = ProposalBatch(
        cell=cells, dirn=jnp.full((b,), 3, jnp.int32),
        u_act=jnp.linspace(0.01, 0.99, b).astype(jnp.float32),
        u_dom=jnp.zeros((b,), jnp.float32))
    te, tem = 0.3, 0.6
    g_seq, _ = reference.run_proposals(grid, batch, te, tem, dom, True,
                                       drop_conflicts=False)
    g_bat, kept = batched.run_proposals(grid, batch, te, tem, dom, True)
    assert int(kept) == b
    assert jnp.array_equal(g_seq, g_bat)


def test_sublattice_single_tile_equals_sequential():
    """One tile covering the lattice -> per-tile sequential sweep must be
    bit-identical to the sequential oracle on interior proposals."""
    key = jax.random.PRNGKey(7)
    h, w = 12, 16
    grid = init_grid(key, h, w, 5, 0.15)
    dom = jnp.asarray(dm.RPSLS())
    te, tem = 0.2, 0.7
    k = 97
    props = tile_proposal_batch(jax.random.PRNGKey(8), 1, k,
                                (h - 2) * (w - 2), 4)
    tile_out = tile_update(
        grid, ProposalBatch(props.cell[0], props.dirn[0], props.u_act[0],
                            props.u_dom[0]), te, tem, dom)
    # map interior window indices to flat lattice cells
    iw = w - 2
    r = 1 + props.cell[0] // iw
    c = 1 + props.cell[0] % iw
    flat = (r * w + c).astype(jnp.int32)
    seq_batch = ProposalBatch(flat, props.dirn[0], props.u_act[0],
                              props.u_dom[0])
    g_seq, _ = reference.run_proposals(grid, seq_batch, te, tem, dom, True)
    assert jnp.array_equal(tile_out, g_seq)


def test_run_round_shift_consistency():
    """Rolling by (dy,dx), updating, rolling back == updating the rolled
    grid: verify run_round's shift plumbing explicitly."""
    key = jax.random.PRNGKey(9)
    grid = init_grid(key, 16, 32, 3, 0.1)
    dom = jnp.asarray(dm.RPS())
    th, tw = 8, 16
    props = tile_proposal_batch(jax.random.PRNGKey(10), 4, 31,
                                (th - 2) * (tw - 2), 4)
    shift = jnp.array([3, 7], jnp.int32)
    out = run_round(grid, props, shift, (th, tw), 0.3, 0.6, dom)
    rolled = jnp.roll(grid, (-3, -7), (0, 1))
    out2 = run_round(rolled, props, jnp.array([0, 0], jnp.int32),
                     (th, tw), 0.3, 0.6, dom)
    assert jnp.array_equal(jnp.roll(out, (-3, -7), (0, 1)), out2)


def test_counts_conserved_under_pure_migration():
    """epsilon-only dynamics permute the lattice: counts exactly conserved
    in every engine."""
    from repro.core import EscgParams, simulate
    for engine in ("reference", "batched", "sublattice"):
        p = EscgParams(length=16, height=16, species=4, mcs=10,
                       mu=0.0, sigma=0.0, epsilon=1.0, engine=engine,
                       tile=(8, 8), chunk_mcs=10, empty=0.2, seed=1)
        res = simulate(p, dm.circulant(4), stop_on_stasis=False)
        np.testing.assert_allclose(res.densities[0], res.densities[-1],
                                   atol=1e-9, err_msg=engine)


@pytest.mark.parametrize("engine", ["reference", "batched", "sublattice",
                                    "pallas", "pallas_fused"])
def test_int8_lattice_bit_equal_to_int32(engine):
    """cell_dtype='int8' (4x less grid HBM traffic) changes nothing
    semantically: bit-equal trajectories in every engine."""
    from repro.core import EscgParams, simulate
    kw = dict(length=32, height=16, species=5, mobility=1e-3, mcs=5,
              engine=engine, tile=(8, 16), chunk_mcs=5, empty=0.1, seed=7)
    r32 = simulate(EscgParams(cell_dtype="int32", **kw), dm.RPSLS(),
                   stop_on_stasis=False)
    r8 = simulate(EscgParams(cell_dtype="int8", **kw), dm.RPSLS(),
                  stop_on_stasis=False)
    assert r8.grid.dtype == np.int8
    np.testing.assert_array_equal(r32.grid, r8.grid.astype(np.int32))
    np.testing.assert_allclose(r32.densities, r8.densities, atol=0)


def test_int8_species_limit():
    from repro.core import EscgParams
    with pytest.raises(ValueError):
        EscgParams(species=200, cell_dtype="int8").validate()


# ----------------------------- engine registry ---------------------------- #

def test_registry_lists_all_engines():
    from repro.core import engine_names, get_engine
    names = engine_names()
    for want in ("reference", "batched", "sublattice", "pallas",
                 "pallas_fused", "sharded"):
        assert want in names
    spec = get_engine("sharded")
    assert spec.caps.multi_device and spec.caps.flux_only
    assert not spec.caps.vmappable
    assert not get_engine("reference").caps.tiled


def test_registry_unknown_engine_raises():
    from repro.core import EscgParams, get_engine
    with pytest.raises(ValueError, match="unknown engine"):
        get_engine("warp_drive")
    with pytest.raises(ValueError, match="unknown engine"):
        EscgParams(engine="warp_drive").validate()


def test_registry_caps_drive_validation():
    from repro.core import EscgParams
    # flux_only engines reject reflecting boundaries
    with pytest.raises(ValueError, match="flux"):
        EscgParams(engine="sublattice", flux=False, tile=(8, 8),
                   length=16, height=16).validate()
    # tiled engines reject non-dividing tiles
    with pytest.raises(ValueError, match="divide"):
        EscgParams(engine="pallas", tile=(7, 8), length=16,
                   height=16).validate()
    # non-tiled engines ignore the tile entirely
    EscgParams(engine="batched", tile=(7, 13), length=16,
               height=16).validate()


def test_k_mcs_validation_is_caps_driven():
    """k_mcs > 1 is a fused-Philox-family capability (EngineCaps.multi_mcs):
    engines without it reject, sharded engines demand local_kernel='fused',
    and k_mcs < 1 is never legal."""
    from repro.core import EscgParams
    with pytest.raises(ValueError, match="k_mcs"):
        EscgParams(k_mcs=0).validate()
    with pytest.raises(ValueError, match="k_mcs"):
        EscgParams(engine="sublattice", tile=(8, 8), length=16, height=16,
                   k_mcs=2).validate()
    with pytest.raises(ValueError, match="fused"):
        EscgParams(engine="sharded", tile=(8, 8), length=16, height=16,
                   local_kernel="jnp", k_mcs=2).validate()
    # the megakernel family accepts it
    EscgParams(engine="pallas_fused", tile=(8, 8), length=16, height=16,
               k_mcs=4).validate()
    EscgParams(engine="sharded", tile=(8, 8), length=16, height=16,
               local_kernel="fused", k_mcs=4).validate()


def test_custom_engine_dispatches_through_simulate():
    """simulate() must resolve engines purely through the registry — a
    third-party registration works with no driver changes."""
    import jax
    from repro.core import EscgParams, engines, simulate

    @engines.register("frozen_test", engines.EngineCaps(
        description="no-op engine for registry dispatch test"))
    def _build(p, dom_):
        def one_mcs(grid, key):
            n = jnp.int32(p.n_cells)
            return grid, n, n
        return engines.BuiltEngine(one_mcs)

    try:
        p = EscgParams(length=8, height=8, species=3, mcs=4, chunk_mcs=2,
                       engine="frozen_test", seed=0)
        res = simulate(p, dm.RPS(), stop_on_stasis=False)
        # the no-op engine never changes the lattice
        np.testing.assert_allclose(res.densities[0], res.densities[-1])
        assert res.mcs_completed == 4
    finally:
        del engines._REGISTRY["frozen_test"]
