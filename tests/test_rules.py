"""The pair-update rule is the single source of truth — validate the
vectorized jnp version against a plain-Python transliteration of the
paper's Algorithm 3.2 under hypothesis-generated inputs."""
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # hermetic container: deterministic fallback sampler
    from _propcheck import given, settings, strategies as st

from repro.core import dominance as dm
from repro.core.rules import apply_pair, apply_pair_reference


@given(
    s=st.integers(0, 5), n=st.integers(0, 5),
    u_act=st.floats(0.0, 0.999), u_dom=st.floats(0.0, 0.999),
    t_eps=st.floats(0.0, 1.0), dt=st.floats(0.0, 1.0),
    alpha=st.floats(0.0, 1.0),
)
@settings(max_examples=300, deadline=None)
def test_apply_pair_matches_algorithm_3_2(s, n, u_act, u_dom, t_eps, dt,
                                          alpha):
    # the engines run in float32; quantize inputs so the python oracle sees
    # the same values (hypothesis loves 1e-88-style denormals)
    u_act, u_dom, t_eps, alpha = (float(np.float32(v)) for v in
                                  (u_act, u_dom, t_eps, alpha))
    t_eps_mu = float(np.float32(min(1.0, t_eps + dt)))
    dom = dm.circulant(5, (1, 2), rate=alpha)
    got = apply_pair(jnp.int32(s), jnp.int32(n), jnp.float32(u_act),
                     jnp.float32(u_dom), t_eps, t_eps_mu,
                     jnp.asarray(dom))
    want = apply_pair_reference(s, n, u_act, u_dom, t_eps, t_eps_mu, dom)
    assert (int(got[0]), int(got[1])) == want


@given(s=st.integers(0, 5), n=st.integers(0, 5), u_act=st.floats(0.0, 0.999),
       u_dom=st.floats(0.0, 0.999))
@settings(max_examples=200, deadline=None)
def test_conservation_laws(s, n, u_act, u_dom):
    """Migration permutes; interaction only empties; reproduction only
    fills an empty with the partner species; nothing invents species."""
    dom = dm.RPSLS()
    t_eps, t_eps_mu = 0.3, 0.6
    ns, nn = apply_pair(jnp.int32(s), jnp.int32(n), jnp.float32(u_act),
                        jnp.float32(u_dom), t_eps, t_eps_mu,
                        jnp.asarray(dom))
    ns, nn = int(ns), int(nn)
    before = {s, n}
    assert {ns, nn} <= before | {0}
    if s == n:
        assert (ns, nn) == (s, n)
    elif u_act < t_eps:                       # migration: exact swap
        assert (ns, nn) == (n, s)
    elif u_act < t_eps_mu:                    # interaction: at most 1 death
        assert sorted([ns, nn]) in (sorted([s, n]), sorted([0, s]),
                                    sorted([0, n]))
        if 0 in (s, n):
            assert (ns, nn) == (s, n)         # empties never interact
    else:                                     # reproduction
        if n == 0:
            assert (ns, nn) == (s, s)
        elif s == 0:
            assert (ns, nn) == (n, n)
        else:
            assert (ns, nn) == (s, n)


def test_vectorized_batch():
    dom = jnp.asarray(dm.RPS())
    s = jnp.array([1, 2, 0, 3, 1], jnp.int32)
    n = jnp.array([2, 2, 1, 1, 0], jnp.int32)
    ua = jnp.array([0.1, 0.5, 0.9, 0.5, 0.9], jnp.float32)
    ud = jnp.zeros(5, jnp.float32)
    ns, nn = apply_pair(s, n, ua, ud, 0.3, 0.6, dom)
    # migration swap; same-species noop; reproduction into self;
    # 3 beats 1 -> cell dies; 1 reproduces into empty neighbour
    np.testing.assert_array_equal(np.asarray(ns), [2, 2, 1, 3, 1])
    np.testing.assert_array_equal(np.asarray(nn), [1, 2, 1, 0, 1])
