"""Minimal, dependency-free stand-in for the hypothesis API subset these
tests use, so the property tests still RUN (deterministic seeded sampling)
in environments without hypothesis installed (e.g. the hermetic accelerator
container). Real hypothesis, when available, is always preferred — see the
try/except imports in the test modules and requirements-dev.txt.

Implemented: given(**kwargs), settings(max_examples=, deadline=),
strategies.integers/floats/booleans/sampled_from/sets.
"""
from __future__ import annotations

import random


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random):
        return self._draw(rng)


class strategies:
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def floats(min_value: float, max_value: float) -> _Strategy:
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def booleans() -> _Strategy:
        return _Strategy(lambda rng: rng.random() < 0.5)

    @staticmethod
    def sampled_from(elements) -> _Strategy:
        elements = list(elements)
        return _Strategy(lambda rng: rng.choice(elements))

    @staticmethod
    def sets(element: _Strategy, min_size: int = 0,
             max_size: int = 10) -> _Strategy:
        def draw(rng):
            out = set()
            # bounded attempts: element domains smaller than min_size
            # would otherwise loop forever
            for _ in range(50 * max(1, max_size)):
                if len(out) >= rng.randint(min_size, max_size):
                    break
                out.add(element.example(rng))
            return out
        return _Strategy(draw)


def settings(max_examples: int = 100, deadline=None, **_ignored):
    def deco(fn):
        fn._propcheck_max_examples = max_examples
        return fn
    return deco


def given(**strats):
    def deco(fn):
        # NB: no functools.wraps — copying fn's signature would make pytest
        # treat the strategy parameters as fixtures. The wrapper must look
        # zero-argument (these property tests use no fixtures).
        def wrapper():
            n = getattr(wrapper, "_propcheck_max_examples", None)
            if n is None:
                n = getattr(fn, "_propcheck_max_examples", 100)
            # deterministic per-test stream: same examples every run
            rng = random.Random(fn.__name__)
            for i in range(n):
                drawn = {k: s.example(rng) for k, s in strats.items()}
                try:
                    fn(**drawn)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example ({i + 1}/{n}): {drawn}") from e
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper
    return deco
