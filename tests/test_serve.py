"""Serving layer (DESIGN.md §12): wire protocol, bucketing, compiled-engine
cache, packed execution, streaming, loadgen and the CLI.

The load-bearing guarantee is the serving contract: every response is
bit-identical to the direct ``run_trials`` / ``simulate`` call it
replaces — whatever other traffic shared the batch — and repeat traffic
for a (bucket, scenario) pair compiles exactly once (cache hit, zero
retraces). Both are asserted here on the real engines; the composed CI
job re-runs this suite on 8 fake devices.
"""
import json
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.core.scenarios import (EngineConfig, RunConfig, make_scenario,
                                  resolve_config, scenario_key)
from repro.core.simulation import simulate
from repro.core.trials import run_trials
from repro.serve import ScenarioServer, SimRequest
from repro.serve.bucketing import AdmissionQueue, BucketKey, Pending, \
    bucket_key
from repro.serve.cache import CompiledEngine, EngineCache
from repro.serve.protocol import SimResponse, parse_request
from repro.serve import loadgen

pytestmark = pytest.mark.composed

# small + deterministic: one compiled shape reused across most tests so
# the module-level compile tax is paid once per interpreter
ENGINE = {"engine": "batched", "tile": [8, 8]}
RUN16 = {"height": 16, "length": 16, "mcs": 10, "chunk_mcs": 5}


def req16(seed=0, mcs=10, n_trials=2, scenario="park3", rid="",
          observables=None):
    run = dict(RUN16, seed=seed, mcs=mcs)
    if observables is not None:
        run["observables"] = observables
    return SimRequest(scenario, engine=ENGINE, run=run,
                      n_trials=n_trials, id=rid)


def direct_trials(req):
    """The ground truth the server must reproduce bit-for-bit."""
    return run_trials(req.scenario, n_trials=req.n_trials,
                      engine=req.engine, run=req.run)


def assert_trial_results_equal(a, b):
    np.testing.assert_array_equal(a.survival, b.survival)
    np.testing.assert_array_equal(a.densities, b.densities)
    np.testing.assert_array_equal(a.stasis_mcs, b.stasis_mcs)
    np.testing.assert_array_equal(a.extinction_mcs, b.extinction_mcs)
    assert a.mcs_completed == b.mcs_completed
    assert a.kept_fraction == b.kept_fraction
    assert a.n_trials == b.n_trials
    assert set(a.observables) == set(b.observables)
    for k in a.observables:
        np.testing.assert_array_equal(a.observables[k], b.observables[k])


# ------------------------------ protocol ----------------------------------- #

class TestProtocol:
    def test_request_constructor_normalizes_wire_shapes(self):
        r = req16(seed=3)
        assert r.scenario.name == "park3"
        assert r.engine.engine == "batched" and r.engine.tile == (8, 8)
        assert r.run.seed == 3 and r.run.chunk_mcs == 5

    def test_request_json_roundtrip(self):
        r = req16(seed=7, n_trials=3, rid="a1")
        r2 = SimRequest.from_json(r.to_json())
        assert r2 == r

    def test_unknown_fields_rejected(self):
        with pytest.raises(ValueError, match="unknown Scenario"):
            parse_request({"scenario": {"name": "x", "speces": 3}})
        with pytest.raises(ValueError, match="unknown EngineConfig"):
            parse_request({"scenario": "park3", "engine": {"engin": "b"}})
        with pytest.raises(ValueError, match="unknown RunConfig"):
            parse_request({"scenario": "park3", "run": {"mc": 5}})
        with pytest.raises(ValueError, match="missing 'scenario'"):
            parse_request({"n_trials": 2})

    def test_response_json_roundtrip_trials(self, server):
        resp = server(req16(seed=11, rid="rt1"))
        assert resp.ok and resp.kind == "trials"
        back = SimResponse.from_json(resp.to_json())
        assert back.id == "rt1" and back.ok and back.kind == "trials"
        assert back.cache_hit == resp.cache_hit
        assert back.bucket == resp.bucket
        assert back.scenario_key == resp.scenario_key
        assert_trial_results_equal(back.result, resp.result)

    def test_error_response_roundtrip(self, server):
        resp = server({"scenario": "no_such_preset", "id": "bad1"})
        assert not resp.ok and resp.kind == "error" and resp.error
        back = SimResponse.from_json(resp.to_json())
        assert back.kind == "error" and back.result is None
        assert back.error == resp.error


# ------------------------------ bucketing ---------------------------------- #

def _resolved(seed=0, mcs=10, scenario="park3", **over):
    r = req16(seed=seed, mcs=mcs, scenario=scenario)
    engine = r.engine.replace(**over) if over else r.engine
    p, _ = resolve_config(r.scenario, None, engine, r.run)
    return p.validate()


class TestBucketing:
    def test_seed_mcs_trials_do_not_move_the_bucket(self):
        assert bucket_key(_resolved(seed=1, mcs=10)) == \
            bucket_key(_resolved(seed=9, mcs=20))

    def test_shape_knobs_move_the_bucket(self):
        b = bucket_key(_resolved())
        assert bucket_key(_resolved(tile=(4, 4))) != b
        assert bucket_key(_resolved(engine="sublattice")) != b

    def test_short_is_human_readable(self):
        s = bucket_key(_resolved()).short()
        assert "batched" in s and "16x16" in s

    def _pend(self, seq, bucket, n_trials=1, skey="k"):
        return Pending(seq=seq, req=req16(n_trials=n_trials),
                       params=None, dom=np.zeros((4, 4)), bucket=bucket,
                       scenario_key=skey, kind="vmap", n_mcs=10)

    def test_pop_batch_age_policy(self):
        a = BucketKey("batched", "jnp", 1, (8, 8), 16, 16, 3, "int32",
                      None, None, 5, (), 0)
        b = a._replace(height=32)
        q = AdmissionQueue()
        q.push(self._pend(1, a))
        q.push(self._pend(2, b))
        q.push(self._pend(3, a))
        gkey, take = q.pop_batch(64)       # a holds the oldest request
        assert gkey[0] == a and [p.seq for p in take] == [1, 3]
        gkey, take = q.pop_batch(64)
        assert gkey[0] == b and len(q) == 0
        assert q.pop_batch(64) is None

    def test_pop_batch_occupancy_beats_age(self):
        a = BucketKey("batched", "jnp", 1, (8, 8), 16, 16, 3, "int32",
                      None, None, 5, (), 0)
        b = a._replace(height=32)
        q = AdmissionQueue()
        q.push(self._pend(1, a))                       # oldest
        q.push(self._pend(2, b, n_trials=64))          # full batch
        gkey, take = q.pop_batch(64)
        assert gkey[0] == b                            # occupancy wins
        gkey, take = q.pop_batch(64)
        assert gkey[0] == a

    def test_pop_batch_respects_trial_cap_but_never_starves(self):
        a = BucketKey("batched", "jnp", 1, (8, 8), 16, 16, 3, "int32",
                      None, None, 5, (), 0)
        q = AdmissionQueue()
        q.push(self._pend(1, a, n_trials=6))
        q.push(self._pend(2, a, n_trials=6))
        _, take = q.pop_batch(8)           # 6 fits, 6+6 does not
        assert [p.seq for p in take] == [1]
        _, take = q.pop_batch(4)           # over-cap request still runs
        assert [p.seq for p in take] == [2]

    def test_depth_keys_distinct_per_group(self):
        """Groups differing only in the sched token (or sharing a hash
        prefix) must not collapse into one queue-depth entry."""
        a = BucketKey("batched", "jnp", 1, (8, 8), 16, 16, 3, "int32",
                      None, None, 5, (), 0)
        q = AdmissionQueue()
        p1 = self._pend(1, a, n_trials=2, skey="deadbeef" + "0" * 56)
        p2 = self._pend(2, a, n_trials=3, skey="deadbeef" + "0" * 56)
        p2.sched = 10
        p3 = self._pend(3, a, n_trials=1, skey="deadbeef" + "f" * 56)
        for p in (p1, p2, p3):
            q.push(p)
        depth = q.depth()
        assert len(depth) == 3              # sched + full hash both kept
        assert sorted(depth.values()) == [1, 2, 3]


# ------------------------------ cache -------------------------------------- #

class TestEngineCache:
    def _entry(self):
        return CompiledEngine(key=None, params=None, dom=np.zeros(1),
                              kind="vmap", chunk_fn=lambda: None,
                              init_fn=lambda: None, counts_fn=lambda: None)

    def test_hit_miss_lru_eviction(self):
        c = EngineCache(max_entries=2)
        e1, hit = c.get_or_build("k1", self._entry)
        assert not hit and e1.key == "k1"
        _, hit = c.get_or_build("k1", self._entry)
        assert hit
        c.get_or_build("k2", self._entry)
        c.get_or_build("k1", self._entry)  # refresh k1 to MRU
        c.get_or_build("k3", self._entry)  # evicts k2 (LRU)
        assert "k2" not in c and "k1" in c and "k3" in c
        acct = c.accounting()
        assert acct == {"entries": 2, "max_entries": 2, "hits": 2,
                        "misses": 3, "evictions": 1, "retraces": 0,
                        "length_traces": 0, "hit_rate": 2 / 5}

    def test_retrace_counter_ignores_first_batch(self):
        c = EngineCache()
        e, _ = c.get_or_build("k", self._entry)
        n = [0]
        e.jit_fns = (type("F", (), {"_cache_size":
                                    staticmethod(lambda: n[0])})(),)
        n[0] = 1
        c.note_run(e)          # first batch: expected compile, no retrace
        assert c.retraces == 0
        c.note_run(e)          # cache static: still none
        assert c.retraces == 0
        n[0] = 2
        c.note_run(e)          # grew on a warm entry: retrace
        assert c.retraces == 1

    def test_new_chunk_length_is_not_a_retrace(self):
        """n_mcs is a static argname, so a warm entry's jit cache grows
        by one for each NEW packed step size — an expected compile
        (counted as length_traces, its wall time handed back for
        compile_s billing), never a retrace. Growth beyond the reported
        lengths still fires."""
        c = EngineCache()
        e, _ = c.get_or_build("k", self._entry)
        n = [0]
        e.jit_fns = (type("F", (), {"_cache_size":
                                    staticmethod(lambda: n[0])})(),)
        n[0] = 1
        assert e.note_chunk_length(5, 0.25)          # first batch: m=5
        c.note_run(e)
        n[0] = 2
        assert e.note_chunk_length(4, 0.125)         # warm entry, new m
        assert not e.note_chunk_length(5)            # already traced
        new, trace_s = c.note_run(e)
        assert (new, trace_s) == (1, 0.125)
        assert c.retraces == 0 and c.length_traces == 1
        n[0] = 3                                     # grew with NO new m
        _, _ = c.note_run(e)
        assert c.retraces == 1


# ------------------------------ server ------------------------------------- #

@pytest.fixture(scope="module")
def server():
    """One warm server shared by the module (compiles are the tax)."""
    return ScenarioServer(max_batch_trials=64, cache_entries=8)


class TestServer:
    def test_packed_batch_bit_identical_to_direct_runs(self, server):
        """Two same-bucket requests with different seeds AND different MCS
        budgets share one batch; each response equals its own direct
        ``run_trials`` call bit-for-bit (observables included — park3
        streams densities + interface_length by default)."""
        ra, rb = req16(seed=3, mcs=10, rid="pk-a"), \
            req16(seed=9, mcs=20, rid="pk-b")
        resps = server.serve([ra, rb])
        assert [r.ok for r in resps] == [True, True]
        assert resps[0].bucket == resps[1].bucket
        assert resps[0].scenario_key == resps[1].scenario_key
        assert server.accounting()["batches"] >= 1
        assert_trial_results_equal(resps[0].result, direct_trials(ra))
        assert_trial_results_equal(resps[1].result, direct_trials(rb))

    def test_early_exit_parity(self, server):
        """A tiny lattice with a long budget reaches stasis early; the
        server's boundary-frozen statistics must match the direct run's
        early-exit exactly (mcs_completed included)."""
        r = SimRequest("park3", engine=ENGINE,
                       run={"height": 8, "length": 8, "mcs": 200,
                            "chunk_mcs": 10, "seed": 5,
                            "observables": ()},
                       n_trials=2, id="early")
        resp = server(r)
        assert resp.ok
        assert_trial_results_equal(resp.result, direct_trials(r))

    def test_cache_hit_no_retrace_on_repeat_bucket(self, server):
        """Same bucket, new seeds/budgets, separate drains: the second
        batch must HIT the cache and must not retrace (same padded
        shape + same chunk schedule => the jitted chunk is reused)."""
        c0 = server.accounting()["cache"]
        r1 = server(req16(seed=21, mcs=10, rid="nr-a"))
        c1 = server.accounting()["cache"]
        r2 = server(req16(seed=22, mcs=20, rid="nr-b"))
        c2 = server.accounting()["cache"]
        assert r1.ok and r2.ok
        assert r2.cache_hit
        assert c2["hits"] == c1["hits"] + 1
        assert c2["misses"] == c1["misses"]
        assert c2["retraces"] == c0["retraces"]
        assert r2.timing["compile_s"] == 0.0

    def test_mixed_buckets_in_one_drain_pack_per_group(self, server):
        """3 scenarios x 2 extents in one submission wave: groups batch
        independently; every response bit-matches its direct run."""
        reqs = [
            req16(seed=31, rid="mx1"),
            req16(seed=32, mcs=20, rid="mx2"),
            req16(seed=33, scenario="zhong_density", rid="mx3"),
            req16(seed=34, scenario="zhong_density", rid="mx4"),
            SimRequest("nspecies5", engine=ENGINE,
                       run=dict(RUN16, seed=35, height=32), n_trials=1,
                       id="mx5"),
            SimRequest("nspecies5", engine=ENGINE,
                       run=dict(RUN16, seed=36, height=32), n_trials=2,
                       id="mx6"),
        ]
        before = server.accounting()["batches"]
        resps = server.serve(reqs)
        assert all(r.ok for r in resps)
        # 3 groups (park3/16, zhong/16, nspecies5/32) -> 3 batches
        assert server.accounting()["batches"] == before + 3
        for req, resp in zip(reqs, resps):
            assert_trial_results_equal(resp.result, direct_trials(req))
        assert server.accounting()["dropped"] == 0

    def test_single_lattice_path_matches_simulate(self, server):
        """The non-vmappable ``sharded`` engine routes to the
        single-lattice path: bit-identical to a direct ``simulate``."""
        sc = make_scenario("park3")
        ec = EngineConfig(engine="sharded", shard_grid=(1, 1), tile=(8, 8))
        rc = RunConfig(height=16, length=16, mcs=10, chunk_mcs=5, seed=4,
                       observables=())
        resp = server(SimRequest(sc, engine=ec, run=rc, id="sg1"))
        assert resp.ok and resp.kind == "single"
        ref = simulate(sc, engine=ec, run=rc)
        np.testing.assert_array_equal(resp.result.grid, ref.grid)
        np.testing.assert_array_equal(resp.result.densities, ref.densities)
        assert resp.result.mcs_completed == ref.mcs_completed
        assert resp.result.stasis_mcs == ref.stasis_mcs

    def test_progress_events_stream_chunk_boundaries(self, server):
        rid = server.submit(req16(seed=41, mcs=20, rid="prog1"))
        assert server.progress(rid) == []       # nothing ran yet
        server.drain()
        events = server.progress(rid)
        assert [e["mcs"] for e in events][-1] == 20
        assert all(e["n_trials"] == 2 for e in events)
        assert events[-1]["done"]
        assert "observables" in events[-1]      # park3 streams by default

    def test_admission_rails_answer_never_drop(self, server):
        errs = server.serve([
            {"scenario": "park3", "n_trials": 0, "id": "e-zero"},
            {"scenario": "park3", "n_trials": 2, "id": "e-single",
             "engine": {"engine": "sharded", "shard_grid": [1, 1],
                        "tile": [8, 8]},
             "run": RUN16},
            {"scenario": "park3", "n_trials": 1, "id": "e-ring",
             "engine": ENGINE,
             "run": dict(RUN16, obs_capacity=2)},
        ])
        assert [e.ok for e in errs] == [False, False, False]
        assert "n_trials" in errs[0].error
        assert "not vmappable" in errs[1].error
        assert "obs_capacity" in errs[2].error
        assert server.accounting()["dropped"] == 0

    def test_mixed_budget_repeat_traffic_is_not_a_retrace(self):
        """The executor packs by nearest boundary, so a repeat bucket can
        run a step size the entry has not traced yet (mcs=6 then mcs=4
        under chunk 5). That first-use trace is expected — zero retraces,
        counted as length_traces, billed to compile_s — and the result
        stays bit-identical to the direct run."""
        srv = ScenarioServer()
        run = dict(RUN16, chunk_mcs=5)
        ra = SimRequest("park3", engine=ENGINE,
                        run=dict(run, seed=91, mcs=6), n_trials=2,
                        id="mb-a")
        rb = SimRequest("park3", engine=ENGINE,
                        run=dict(run, seed=92, mcs=4), n_trials=2,
                        id="mb-b")
        resp_a = srv(ra)
        resp_b = srv(rb)
        assert resp_a.ok and resp_b.ok
        cache = srv.accounting()["cache"]
        assert cache["retraces"] == 0, cache
        assert cache["hits"] == 1 and cache["misses"] == 1
        assert cache["length_traces"] >= 1          # m=4 traced on hit
        assert resp_b.cache_hit
        assert resp_b.timing["compile_s"] > 0.0     # trace billed here
        assert_trial_results_equal(resp_a.result, direct_trials(ra))
        assert_trial_results_equal(resp_b.result, direct_trials(rb))

    def test_engine_build_failure_answers_every_request(self,
                                                        monkeypatch):
        """A build that passes admission but fails in step() must answer
        every popped request with an error response — drain() returns
        instead of raising, and accounting shows zero dropped."""
        from repro.serve import server as server_mod
        srv = ScenarioServer()

        def boom(params, dom):
            raise RuntimeError("engine build exploded")

        monkeypatch.setattr(server_mod, "build_entry", boom)
        ids = [srv.submit(req16(seed=95, rid="bf-a")),
               srv.submit(req16(seed=96, rid="bf-b"))]
        assert srv.drain() == 2                     # no exception
        for rid in ids:
            resp = srv.response(rid)
            assert resp is not None and not resp.ok
            assert "engine build exploded" in resp.error
            assert resp.timing["compile_s"] >= 0.0
        acct = srv.accounting()
        assert acct["dropped"] == 0 and acct["errors"] == 2

    def test_infeasible_mesh_rejected_at_admission(self):
        """A device layout this host cannot satisfy is answered at
        admission (it could only ever fail the engine build)."""
        srv = ScenarioServer()
        resp = srv({"scenario": "park3", "n_trials": 1, "id": "mesh1",
                    "engine": {"engine": "sharded_pod",
                               "mesh_shape": [64, 2, 2], "tile": [8, 8]},
                    "run": RUN16})
        assert not resp.ok and "devices" in resp.error
        assert srv.accounting()["dropped"] == 0

    def test_response_retention_bounded_and_ack(self):
        """Retention: answered responses beyond max_responses evict
        oldest-first without ever reading as a drop; ack() releases a
        response eagerly."""
        srv = ScenarioServer(max_responses=2)
        ids = [srv.submit(req16(seed=86 + i, rid=f"ret-{i}"))
               for i in range(3)]
        srv.drain()
        acct = srv.accounting()
        assert acct["responded"] == 3 and acct["dropped"] == 0
        assert acct["retained"] == 2 and acct["evicted"] == 1
        assert srv.response(ids[0]) is None          # oldest evicted
        assert srv.progress(ids[0]) == []            # events went with it
        assert srv.response(ids[1]).ok
        acked = srv.ack(ids[1])
        assert acked is not None and acked.ok
        assert srv.ack(ids[1]) is None               # already released
        assert srv.accounting()["retained"] == 1
        assert srv.accounting()["dropped"] == 0      # acks never drop

    def test_duplicate_id_answered_without_clobbering_original(self,
                                                               server):
        r1 = server(req16(seed=51, rid="dup"))
        assert r1.ok
        rid = server.submit(req16(seed=52, rid="dup"))
        assert rid != "dup"                      # answered under a fresh id
        resp = server.response(rid)
        assert resp is not None and not resp.ok
        assert "duplicate" in resp.error
        assert server.response("dup").ok         # original intact

    def test_responses_in_submit_order_and_accounting_consistent(self,
                                                                 server):
        acct = server.accounting()
        assert acct["requests"] == acct["responded"] + acct["pending"]
        assert acct["dropped"] == 0
        assert acct["latency"]["total"]["count"] >= 1
        assert 0.0 < acct["cache"]["hit_rate"] <= 1.0
        ids = [r.id for r in server.responses()]
        assert ids == [i for i in server._order if i in server._responses]


# ------------------------------ http adapter ------------------------------- #

def test_http_adapter_roundtrip(server):
    from repro.serve.httpd import serve_http
    httpd, thread = serve_http(server, port=0, background=True)
    try:
        base = "http://127.0.0.1:%d" % httpd.server_address[1]

        def post(path, payload=None):
            data = json.dumps(payload).encode() if payload is not None \
                else b""
            r = urllib.request.Request(base + path, data=data,
                                       method="POST")
            with urllib.request.urlopen(r) as f:
                return json.loads(f.read())

        def get(path):
            with urllib.request.urlopen(base + path) as f:
                return json.loads(f.read())

        assert get("/healthz") == {"ok": True}
        wire = req16(seed=61, rid="http1").to_wire()
        assert post("/submit", wire) == {"ids": ["http1"]}
        assert post("/drain")["answered"] >= 1
        resp = get("/response?id=http1")
        assert resp["ok"] and resp["kind"] == "trials"
        assert resp["result"]["n_trials"] == 2
        assert get("/progress?id=http1")["events"]
        assert get("/accounting")["dropped"] == 0
        assert post("/ack?id=http1")["ok"]       # released, still a reply
        with pytest.raises(urllib.error.HTTPError):
            get("/response?id=http1")            # 404 once acked
        assert get("/accounting")["dropped"] == 0
    finally:
        httpd.shutdown()
        httpd.server_close()


# ------------------------------ loadgen ------------------------------------ #

class TestLoadgen:
    def test_synthetic_trace_deterministic_and_mixed(self):
        a, b = loadgen.synthetic_trace(10, 0), loadgen.synthetic_trace(10, 0)
        assert a == b and len(a) == 10
        scenarios = {r["scenario"] for r in a}
        extents = {(r["run"]["height"], r["run"]["length"]) for r in a}
        assert len(scenarios) >= 3 and len(extents) >= 2
        assert loadgen.synthetic_trace(10, 1) != a   # seed moves seeds

    def test_trace_file_roundtrip(self, tmp_path):
        path = str(tmp_path / "t.jsonl")
        reqs = loadgen.synthetic_trace(4, 2)
        loadgen.write_trace(path, reqs)
        with open(path) as f:
            assert len(f.read().strip().splitlines()) == 4
        assert loadgen.read_trace(path) == reqs

    def test_replay_report_and_gate_row(self, server, tmp_path):
        reqs = [req16(seed=71, rid="lg1").to_wire(),
                req16(seed=72, mcs=20, rid="lg2").to_wire()]
        c0 = server.accounting()["cache"]
        report = loadgen.replay(server, reqs, waves=2)
        assert report["schema"] == loadgen.REPORT_SCHEMA
        assert report["n_requests"] == 4 and report["n_ok"] == 4
        assert report["dropped"] == 0
        assert report["updates"] > 0 and report["updates_per_s"] > 0
        # wave 2 re-forms the bucket -> at least one cache hit
        assert report["cache"]["hits"] >= c0["hits"] + 1
        assert loadgen.check_report(report) == []
        row = loadgen.gate_row(report)
        assert row["family"] == "serve" and row["dropped"] == 0
        assert row["requests_per_s"] > 0 and row["us_per_call"] > 0
        from benchmarks import bench_gate as bg
        assert bg.validate_gate_row(row) == []

    def test_check_report_flags_problems(self):
        bad = {"schema": "nope", "dropped": 1, "n_error": 2,
               "cache": {"hits": 0}}
        problems = loadgen.check_report(bad)
        assert len(problems) == 4
        joined = " ".join(problems)
        assert "schema" in joined and "dropped=1" in joined
        assert "n_error=2" in joined and "hits=0" in joined


def test_committed_smoke_trace_is_mixed_and_packs():
    """The CI serve-smoke trace: >= 3 scenarios x >= 2 lattice extents,
    and every admission group holds >= 2 requests, so the queue actually
    packs (admission only — the replay itself runs in CI)."""
    import os
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    reqs = loadgen.read_trace(
        os.path.join(repo, "examples", "traces", "smoke.jsonl"))
    assert len(reqs) == 10
    assert len({r["scenario"] for r in reqs}) >= 3
    assert len({(r["run"]["height"], r["run"]["length"])
                for r in reqs}) >= 2
    srv = ScenarioServer()
    groups = {}
    for i, r in enumerate(reqs):
        pend = srv._admit(i + 1, parse_request(r))
        groups.setdefault(pend.group, []).append(pend)
    assert len(groups) >= 4
    assert all(len(v) >= 2 for v in groups.values()), {
        k[0].short(): len(v) for k, v in groups.items()}


# ------------------------------ CLI ---------------------------------------- #

class TestCli:
    def test_emit_trace_roundtrip(self, tmp_path):
        from repro.launch.serve import main
        path = str(tmp_path / "trace.jsonl")
        assert main(["--emitTrace", path, "--synthetic", "4"]) == 0
        assert loadgen.read_trace(path) == loadgen.synthetic_trace(4, 0)

    def test_replay_check_and_report(self, tmp_path, capsys):
        from repro.launch.serve import main
        trace = str(tmp_path / "t.jsonl")
        report = str(tmp_path / "report.json")
        loadgen.write_trace(trace, [req16(seed=81, rid="c1").to_wire(),
                                    req16(seed=82, rid="c2").to_wire()])
        rc = main(["--trace", trace, "--waves", "2",
                   "--report", report, "--check"])
        captured = capsys.readouterr()
        assert rc == 0, captured.err
        with open(report) as f:
            rep = json.load(f)
        assert rep["schema"] == loadgen.REPORT_SCHEMA
        assert rep["n_requests"] == 4 and rep["cache"]["hits"] >= 1
        assert "req/s" in captured.out

    def test_help_is_escg_not_lm_scaffold(self):
        from repro.launch.serve import build_parser
        text = build_parser().format_help()
        assert "scenario server" in text
        for lm_word in ("granite", "prefill", "decode"):
            assert lm_word not in text.lower()


def test_lm_scaffold_quarantined():
    """Satellite: train.py / train_lib.py are marked as quarantined
    LM-scaffold appendix code, and the launch package advertises only
    ESCG entry points."""
    import os
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

    def head(path):
        with open(os.path.join(repo, path)) as f:
            return f.read(600)

    assert "LM-scaffold appendix" in head("src/repro/launch/train.py")
    assert "NOT an ESCG entry point" in head("src/repro/launch/train.py")
    assert "LM-scaffold appendix" in head("src/repro/runtime/train_lib.py")
    init = head("src/repro/launch/__init__.py")
    assert "escg_run" in init and "quarantined" in init
    with open(os.path.join(repo, "pyproject.toml")) as f:
        pyproject = f.read()
    assert 'escg_serve = "repro.launch.serve:main"' in pyproject


# --------------------- multi-device no-retrace (slow) ---------------------- #

@pytest.mark.slow
@pytest.mark.parametrize("engine", ["sublattice", "sharded", "sharded_pod"])
def test_no_retrace_and_bit_identity_multidevice(subproc, engine):
    """On 8 fake devices: two same-bucket requests (different seeds and
    MCS budgets) compile exactly once — one miss, one hit, zero
    retraces — and each response is bit-identical to its direct
    ``run_trials`` / ``simulate`` call."""
    code = """
        import json
        import numpy as np
        from repro.core.scenarios import (EngineConfig, RunConfig,
                                          make_scenario)
        from repro.core.simulation import simulate
        from repro.core.trials import run_trials
        from repro.serve import ScenarioServer, SimRequest

        engine = %r
        single = engine == "sharded"
        sc = make_scenario("park3")
        if engine == "sharded_pod":
            ec = EngineConfig(engine=engine, mesh_shape=(2, 2, 2),
                              tile=(8, 8))
        elif engine == "sharded":
            ec = EngineConfig(engine=engine, shard_grid=(2, 2),
                              tile=(8, 8))
        else:
            ec = EngineConfig(engine=engine, tile=(8, 8))
        def rc(seed, mcs):
            return RunConfig(height=32, length=32, mcs=mcs, chunk_mcs=4,
                             seed=seed, observables=())
        n = 1 if single else 4
        ra = SimRequest(sc, engine=ec, run=rc(3, 8), n_trials=n, id="a")
        rb = SimRequest(sc, engine=ec, run=rc(9, 16), n_trials=n, id="b")

        srv = ScenarioServer()
        resp_a = srv(ra)
        resp_b = srv(rb)
        assert resp_a.ok, resp_a.error
        assert resp_b.ok, resp_b.error
        cache = srv.accounting()["cache"]
        assert cache["misses"] == 1, cache
        assert cache["hits"] == 1, cache
        assert cache["retraces"] == 0, cache
        assert resp_b.cache_hit and not resp_a.cache_hit

        for req, resp in ((ra, resp_a), (rb, resp_b)):
            if single:
                ref = simulate(sc, engine=ec, run=req.run)
                np.testing.assert_array_equal(resp.result.grid, ref.grid)
                np.testing.assert_array_equal(resp.result.densities,
                                              ref.densities)
                assert resp.result.mcs_completed == ref.mcs_completed
            else:
                ref = run_trials(sc, n_trials=req.n_trials, engine=ec,
                                 run=req.run)
                np.testing.assert_array_equal(resp.result.survival,
                                              ref.survival)
                np.testing.assert_array_equal(resp.result.densities,
                                              ref.densities)
                np.testing.assert_array_equal(resp.result.stasis_mcs,
                                              ref.stasis_mcs)
                np.testing.assert_array_equal(resp.result.extinction_mcs,
                                              ref.extinction_mcs)
                assert resp.result.mcs_completed == ref.mcs_completed
        print(json.dumps({"ok": True, "cache": cache}))
    """ % (engine,)
    out = subproc(code, 8)
    assert json.loads(out.strip().splitlines()[-1])["ok"]
