import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.spec import ParamSpec, abstract, initialize
from repro.optim import (adafactor, adamw, clip_by_global_norm,
                         cosine_schedule, optimizers)


def _tiny_tree():
    return {"a": {"w": ParamSpec((4, 8), ("embed", "ffn"))},
            "b": ParamSpec((8,), (None,), init="zeros")}


def test_adamw_matches_manual():
    opt = adamw(b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0)
    specs = _tiny_tree()
    params = initialize(specs, jax.random.PRNGKey(0))
    state = initialize(opt.state_specs(specs), jax.random.PRNGKey(1))
    grads = jax.tree.map(lambda p: jnp.ones_like(p) * 0.5, params)
    new_p, new_s = opt.apply(params, grads, state, jnp.float32(0.1),
                             jnp.int32(0))
    # manual first step: m=0.05, v=0.00025; bias-corr: mh=0.5, vh=0.25
    # u = 0.5/(0.5+1e-8) ~= 1 -> p' = p - 0.1
    w0 = np.asarray(params["a"]["w"])
    w1 = np.asarray(new_p["a"]["w"])
    np.testing.assert_allclose(w1, w0 - 0.1, atol=1e-5)
    np.testing.assert_allclose(np.asarray(new_s["a"]["w"]["m"]), 0.05,
                               atol=1e-7)


def test_adamw_chunked_layer_axis_equivalent():
    """The lax.map layer-chunked path must equal the direct update."""
    opt = adamw()
    specs = {"w": ParamSpec((6, 4, 8), ("layers", "embed", "ffn"))}
    params = initialize(specs, jax.random.PRNGKey(0))
    state = initialize(opt.state_specs(specs), jax.random.PRNGKey(1))
    grads = initialize(specs, jax.random.PRNGKey(2))
    new_p, _ = opt.apply(params, grads, state, jnp.float32(0.01),
                         jnp.int32(3))
    # direct per-slice computation
    for i in range(6):
        pi = {"w": params["w"][i]}
        si = {"w": {"m": state["w"]["m"][i], "v": state["w"]["v"][i]}}
        gi = {"w": grads["w"][i]}
        out_i, _ = opt.apply(pi, gi, si, jnp.float32(0.01), jnp.int32(3))
        np.testing.assert_allclose(np.asarray(new_p["w"][i]),
                                   np.asarray(out_i["w"]), atol=1e-6)


def test_adafactor_memory_factored():
    opt = adafactor()
    specs = {"w": ParamSpec((64, 128), ("embed", "ffn"))}
    st = opt.state_specs(specs)
    assert st["w"]["vr"].shape == (64,)
    assert st["w"]["vc"].shape == (128,)


def test_adafactor_descends_quadratic():
    opt = adafactor()
    specs = {"w": ParamSpec((8, 8), ("embed", "ffn"))}
    params = initialize(specs, jax.random.PRNGKey(0))
    state = initialize(opt.state_specs(specs), jax.random.PRNGKey(1))
    target = initialize(specs, jax.random.PRNGKey(5))

    def loss(p):
        return jnp.sum((p["w"] - target["w"]) ** 2)

    l0 = float(loss(params))
    for step in range(50):
        grads = jax.grad(loss)(params)
        params, state = opt.apply(params, grads, state, jnp.float32(0.05),
                                  jnp.int32(step))
    assert float(loss(params)) < 0.2 * l0


def test_global_norm_clip():
    grads = {"a": jnp.ones((3,)) * 4.0}          # norm ~ 6.93
    clipped, norm = clip_by_global_norm(grads, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(48), rel=1e-5)
    got = float(jnp.linalg.norm(clipped["a"]))
    assert got == pytest.approx(1.0, rel=1e-3)
    # no-op below the threshold
    small = {"a": jnp.ones((3,)) * 0.1}
    same, _ = clip_by_global_norm(small, 1.0)
    np.testing.assert_allclose(np.asarray(same["a"]), 0.1, atol=1e-6)


def test_cosine_schedule_shape():
    sch = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(sch(jnp.int32(0))) == 0.0
    assert float(sch(jnp.int32(10))) == pytest.approx(1e-3, rel=1e-4)
    assert float(sch(jnp.int32(100))) == pytest.approx(1e-4, rel=1e-3)
    assert float(sch(jnp.int32(55))) < 1e-3
