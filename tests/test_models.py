"""Per-arch smoke tests (reduced configs) + component-level correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.configs.base import ShapeConfig
from repro.models import build_model
from repro.models import common, moe as moe_mod, ssm as ssm_mod
from repro.models import spec as spec_mod

KEY = jax.random.PRNGKey(0)
TRAIN = ShapeConfig("t", 32, 2, "train")


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_smoke_forward_and_train_step(arch):
    """Reduced config: one forward/train step on CPU; shapes + finiteness."""
    from repro.runtime import train_lib
    cfg = ARCHS[arch].reduced()
    model = build_model(cfg)
    state = train_lib.init_state(model, KEY)
    batch = model.concrete_inputs(TRAIN, KEY)
    assert batch["tokens"].shape == (2, 32)
    step = jax.jit(train_lib.make_train_step(model))
    state2, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(state2["step"]) == 1
    # params actually changed
    p0 = jax.tree.leaves(state["params"])[0]
    p1 = jax.tree.leaves(state2["params"])[0]
    assert not np.allclose(np.asarray(p0), np.asarray(p1))


@pytest.mark.parametrize("arch", ["granite-3-8b", "qwen1.5-32b",
                                  "falcon-mamba-7b", "zamba2-7b",
                                  "whisper-small", "kimi-k2-1t-a32b"])
def test_prefill_decode_matches_full_forward(arch):
    """Prefill T tokens then decode token T+1 == full forward over T+1
    tokens: the strongest KV/SSM-cache correctness check."""
    cfg = ARCHS[arch].reduced()
    if cfg.family == "moe":
        # capacity drops legitimately differ between a 13-token prefill and
        # a 1-token decode; give ample capacity so routing is drop-free
        cfg = cfg.replace(moe_cf=8.0)
    model = build_model(cfg)
    params = model.init(KEY)
    t = 12
    pre = model.concrete_inputs(ShapeConfig("p", t + 1, 2, "prefill"), KEY)
    full_tokens = pre["tokens"]

    batch_t = dict(pre, tokens=full_tokens[:, :t])
    logits_t, cache = model.prefill(params, batch_t, max_len=t + 4)
    logits_step, _ = model.decode_step(params, cache, full_tokens[:, t])

    logits_full, _ = model.prefill(params, pre, max_len=t + 4)
    np.testing.assert_allclose(np.asarray(logits_step),
                               np.asarray(logits_full),
                               atol=2e-2, rtol=2e-2)


def test_moe_layer_matches_dense_loop():
    """Capacity-dispatch einsum MoE == explicit per-token expert loop when
    capacity is ample (no drops)."""
    cfg = ARCHS["grok-1-314b"].reduced().replace(
        moe_experts=4, moe_topk=2, moe_dff=32, moe_cf=8.0, moe_groups=1)
    p = spec_mod.initialize(moe_mod.moe_specs(cfg), KEY)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model),
                          jnp.float32)
    y, aux = moe_mod.moe_layer(p, x, cfg)
    assert np.isfinite(float(aux))

    # reference: route each token independently
    logits = x @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    topv, topi = jax.lax.top_k(probs, 2)
    topv = topv / topv.sum(-1, keepdims=True)
    want = np.zeros_like(np.asarray(x))
    xn = np.asarray(x)
    for b in range(2):
        for s in range(8):
            for j in range(2):
                e = int(topi[b, s, j])
                h = xn[b, s] @ np.asarray(p["wi"][e])
                hg = xn[b, s] @ np.asarray(p["wg"][e])
                h = h / (1 + np.exp(-h)) * hg
                want[b, s] += float(topv[b, s, j]) * (
                    h @ np.asarray(p["wo"][e]))
    np.testing.assert_allclose(np.asarray(y), want, atol=2e-4, rtol=2e-3)


def test_mamba1_chunked_scan_matches_sequential():
    a = jax.random.uniform(KEY, (2, 16, 4, 3), jnp.float32, 0.5, 0.99)
    bu = jax.random.normal(jax.random.PRNGKey(2), (2, 16, 4, 3))
    h0 = jnp.zeros((2, 4, 3))
    h_all, h_last = ssm_mod._ssm_scan_chunked(a, bu, h0, chunk=4)

    h = h0
    outs = []
    for t in range(16):
        h = a[:, t] * h + bu[:, t]
        outs.append(h)
    want = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(h_all), np.asarray(want),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(h_last), np.asarray(want[:, -1]),
                               atol=1e-5, rtol=1e-5)


def test_mamba1_fused_equals_reference_scan():
    """The fused (HBM-frugal) selective scan == the materializing spec."""
    b, s, di, n = 2, 32, 6, 4
    k = jax.random.PRNGKey(4)
    xc = jax.random.normal(k, (b, s, di))
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(5),
                                           (b, s, di)))
    bs = jax.random.normal(jax.random.PRNGKey(6), (b, s, n))
    cs = jax.random.normal(jax.random.PRNGKey(7), (b, s, n))
    a = -jnp.exp(jax.random.normal(jax.random.PRNGKey(8), (di, n)))
    dsk = jnp.ones((di,))
    h0 = jax.random.normal(jax.random.PRNGKey(9), (b, di, n))
    y, hl = ssm_mod._ssm_scan_fused(xc, dt, bs, cs, a, dsk, h0, chunk=8)
    da = jnp.exp(dt[..., None] * a)
    bu = (dt * xc)[..., None] * bs[:, :, None, :]
    h_all, hl2 = ssm_mod._ssm_scan_chunked(da, bu, h0, chunk=8)
    y2 = jnp.einsum("bsdn,bsn->bsd", h_all, cs) + xc * dsk
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2), atol=1e-5,
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(hl), np.asarray(hl2), atol=1e-5,
                               rtol=1e-5)


def test_mamba2_chunk_invariance():
    """SSD output must not depend on the chunk size."""
    cfg = ARCHS["zamba2-7b"].reduced()
    p = spec_mod.initialize(ssm_mod.mamba2_specs(cfg), KEY)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 16, cfg.d_model),
                          jnp.float32) * 0.5
    y1, st1 = ssm_mod.mamba2_forward(p, x, cfg.replace(ssm_chunk=4))
    y2, st2 = ssm_mod.mamba2_forward(p, x, cfg.replace(ssm_chunk=16))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(st1["ssm"]),
                               np.asarray(st2["ssm"]), atol=1e-4, rtol=1e-4)


def test_attention_chunked_matches_full():
    b, s, h, kv, hd = 2, 32, 8, 4, 16
    q = jax.random.normal(KEY, (b, s, h, hd), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, kv, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, kv, hd))
    full = common.gqa_attention(q, k, v, causal=True, chunk=0)
    chunked = common.gqa_attention(q, k, v, causal=True, chunk=8)
    np.testing.assert_allclose(np.asarray(full), np.asarray(chunked),
                               atol=1e-5, rtol=1e-5)
    # decode mode: kv_len masking == truncated cache
    q1 = q[:, :1]
    kl = 20
    dec = common.gqa_attention(q1, k, v, causal=False, q_offset=kl - 1,
                               kv_len=kl, chunk=0)
    ref = common.gqa_attention(q1, k[:, :kl], v[:, :kl], causal=False,
                               chunk=0)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(ref), atol=1e-5,
                               rtol=1e-5)


def test_rotary_relative_shift_invariance():
    """Rotary dot products depend only on relative positions."""
    hd = 16
    q = jax.random.normal(KEY, (1, 4, 1, hd), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 4, 1, hd))
    def scores(offset):
        pos = jnp.arange(4) + offset
        qr = common.rotary(q, pos, 1e4)
        kr = common.rotary(k, pos, 1e4)
        return jnp.einsum("bshd,bthd->bst", qr, kr)
    np.testing.assert_allclose(np.asarray(scores(0)),
                               np.asarray(scores(37)), atol=1e-4, rtol=1e-3)


def test_vocab_padding_masked():
    cfg = ARCHS["granite-3-8b"].reduced().replace(vocab=100)
    assert cfg.vocab_padded == 256
    model = build_model(cfg)
    params = model.init(KEY)
    batch = model.concrete_inputs(ShapeConfig("p", 8, 1, "prefill"), KEY)
    logits, _ = model.prefill(params, batch, max_len=8)
    assert logits.shape[-1] == 256
    assert np.all(np.asarray(logits)[..., 100:] <= -1e29)


def test_param_counts_full_configs():
    """Full (unreduced) configs — abstract only, no allocation."""
    expect = {
        "granite-3-8b": (7e9, 10e9),
        "qwen1.5-32b": (30e9, 36e9),
        "yi-9b": (8e9, 10e9),
        "kimi-k2-1t-a32b": (0.95e12, 1.15e12),
        "grok-1-314b": (3.0e11, 3.4e11),
        "falcon-mamba-7b": (6e9, 9e9),
        "zamba2-7b": (6e9, 9e9),
        "whisper-small": (2e8, 5e8),
    }
    for arch, (lo, hi) in expect.items():
        n = build_model(ARCHS[arch]).n_params()
        assert lo <= n <= hi, f"{arch}: {n:,}"
    kimi = build_model(ARCHS["kimi-k2-1t-a32b"])
    assert kimi.n_active_params() < 0.05 * kimi.n_params()
