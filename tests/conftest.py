"""Shared test fixtures. NOTE: no XLA_FLAGS here — unit tests run on the
single real CPU device; multi-device tests spawn subprocesses that set
--xla_force_host_platform_device_count themselves."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")

# Suites the CI `composed` job (8 fake devices, `-m composed`) must cover:
# marker-driven selection replaced a hardcoded file list that silently
# missed newly added modules, so guard the floor here — a refactor that
# drops the marker from one of these files fails collection everywhere.
COMPOSED_REQUIRED = {"test_engine_equivalence.py", "test_trials.py",
                     "test_golden.py"}


def pytest_collection_modifyitems(config, items):
    unmarked = sorted({
        os.path.basename(str(item.fspath)) for item in items
        if os.path.basename(str(item.fspath)) in COMPOSED_REQUIRED
        and item.get_closest_marker("composed") is None})
    if unmarked:
        raise pytest.UsageError(
            f"suites {unmarked} must carry the 'composed' marker "
            "(pytestmark = pytest.mark.composed) — the CI composed-mesh "
            "job selects tests with -m composed")


def run_with_devices(code: str, n_devices: int, timeout: int = 420) -> str:
    """Run python `code` in a subprocess with N fake CPU devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count="
                        f"{n_devices}")
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env)
    assert out.returncode == 0, (
        f"subprocess failed:\nSTDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}")
    return out.stdout


@pytest.fixture(scope="session")
def subproc():
    return run_with_devices
