"""Benchmark smoke tests: every benchmarks/*.py module runs end-to-end in
its tiny ``ESCG_BENCH_SMOKE=1`` configuration (benchmarks/common.py) and
emits at least one well-formed CSV row — benchmark code can never silently
rot behind the paper figures it reproduces (DESIGN.md §7). Plus fast
in-process tests of the gate machinery itself: the (fixed) median, the v3
row/document schema, and the trajectory-regression compare that the
perf-smoke CI job runs with --compare."""
import copy
import glob
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if REPO not in sys.path:          # `import benchmarks.*` from the repo root
    sys.path.insert(0, REPO)

# roofline_table legitimately emits nothing without dry-run records; it
# must still exit cleanly
_MAY_BE_EMPTY = {"roofline_table"}

MODULES = sorted(
    os.path.basename(p)[:-3]
    for p in glob.glob(os.path.join(REPO, "benchmarks", "*.py"))
    if os.path.basename(p) not in ("common.py", "run.py", "__init__.py"))


def _run_smoke(module: str, extra_env=None) -> str:
    env = dict(os.environ)
    env["ESCG_BENCH_SMOKE"] = "1"
    env["PYTHONPATH"] = (os.path.join(REPO, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    env.update(extra_env or {})
    out = subprocess.run(
        [sys.executable, "-m", f"benchmarks.{module}"], cwd=REPO,
        capture_output=True, text=True, timeout=420, env=env)
    assert out.returncode == 0, (
        f"benchmarks.{module} smoke run failed:\nSTDOUT:\n{out.stdout}\n"
        f"STDERR:\n{out.stderr}")
    return out.stdout


def _assert_csv_rows(module: str, stdout: str) -> None:
    rows = [ln for ln in stdout.splitlines()
            if ln and not ln.startswith("#")]
    if module in _MAY_BE_EMPTY and not rows:
        return
    assert rows, f"benchmarks.{module} emitted no CSV rows:\n{stdout}"
    for ln in rows:
        parts = ln.split(",")
        assert len(parts) >= 2, f"malformed row from {module}: {ln!r}"
        float(parts[1])          # us_per_call must parse
        assert "ERROR" not in ln, f"benchmark errored: {ln!r}"


def test_modules_discovered():
    assert len(MODULES) >= 9, MODULES


@pytest.mark.slow
@pytest.mark.parametrize("module", MODULES)
def test_benchmark_smoke(module):
    _assert_csv_rows(module, _run_smoke(module))


# -------------------- timing statistics (common.py) ----------------------- #

def test_median_even_and_odd():
    """The regression this PR fixes: ``sorted[n // 2]`` is the MAX of a
    2-sample run (exactly what the gate used to time with iters=2)."""
    from benchmarks.common import median
    assert median([3.0, 1.0, 2.0]) == 2.0           # odd: middle element
    assert median([1.0, 2.0, 3.0, 4.0]) == 2.5      # even: mean of middle 2
    assert median([10.0, 2.0]) == 6.0               # NOT max(10.0)
    assert median([5.0]) == 5.0
    with pytest.raises(ValueError):
        median([])


def test_time_stats_true_median(monkeypatch):
    """time_stats must report the true median over a scripted clock — the
    even-iters case returns the midpoint, never the slower sample."""
    from benchmarks import common

    ticks = iter([0.0, 1.0,      # call 1: 1 s
                  1.0, 4.0,      # call 2: 3 s
                  4.0, 6.0,      # call 3: 2 s
                  6.0, 11.0])    # call 4: 5 s
    monkeypatch.setattr(common.time, "perf_counter", lambda: next(ticks))
    stats = common.time_stats(lambda: None, warmup=0, iters=4)
    assert stats == {"median_us": 2.5e6, "mean_us": 2.75e6,
                     "min_us": 1e6, "max_us": 5e6, "n": 4}


# ------------------- gate schema + trajectory compare ---------------------- #

def _serve_gate_row():
    """A minimal schema-valid family-``serve`` row (v5)."""
    return {
        "name": "serve_throughput_smoke", "family": "serve",
        "scenario": "mixed", "local_kernel": "mixed", "engine": "server",
        "backend": "cpu", "observables": False, "us_per_call": 5e5,
        "derived": "2.00 req/s, 0.010 Mupd/s", "n_requests": 20,
        "requests_per_s": 2.0, "updates_per_s": 1e4,
        "cache_hits": 5, "cache_misses": 5, "dropped": 0,
    }


def _gate_doc():
    """A minimal schema-valid v5 document covering every required local
    kernel and scenario, one observable-overhead pair and the serving
    throughput row."""
    from benchmarks import bench_gate as bg

    def row(kernel, scenario, observables=False):
        suffix = "_obs" if observables else ""
        return {
            "name": f"kernelgate_{scenario}_sublattice_{kernel}{suffix}",
            "us_per_call": 100.0, "derived": "1.0 Mupd/s",
            "family": "sublattice", "scenario": scenario,
            "local_kernel": kernel, "engine": "sublattice",
            "backend": "cpu", "observables": observables,
            "lattice": [16, 32], "mcs": 2,
            "n_trials": 0, "n_pad": 0, "updates_per_s": 1e6,
            "timing": {"median_us": 100.0, "mean_us": 110.0,
                       "min_us": 90.0, "max_us": 140.0, "n": 3},
        }
    rows = [row(k, bg.SCENARIOS[0]) for k in bg.LOCAL_KERNELS]
    rows += [row("jnp", sc) for sc in bg.SCENARIOS[1:]]
    rows += [row("jnp", bg.SCENARIOS[0], observables=True)]
    rows += [_serve_gate_row()]
    return {"schema": bg.SCHEMA, "backend": "cpu", "devices": 1,
            "smoke": True, "unix_time": 1700000000, "rows": rows}


def test_gate_document_schema_v4():
    from benchmarks import bench_gate as bg
    doc = _gate_doc()
    assert bg.validate_gate_document(doc) == []
    # v4 rows must declare whether the observable pipeline ran
    bad = copy.deepcopy(doc)
    del bad["rows"][0]["observables"]
    assert any("observables" in e for e in bg.validate_gate_document(bad))
    # ...and the flag is part of the trajectory identity: an obs-on row
    # never gates against its off twin
    on = next(r for r in doc["rows"] if r["observables"])
    off = next(r for r in doc["rows"] if not r["observables"]
               and r["local_kernel"] == on["local_kernel"]
               and r["scenario"] == on["scenario"])
    assert bg.row_key(on) != bg.row_key(off)
    # older v3 history entries (no observables field) still validate when
    # the caller accepts historical schemas, but not as a fresh document
    v3 = copy.deepcopy(doc)
    v3["schema"] = bg.SCHEMA_V3
    v3["rows"] = [r for r in v3["rows"] if r["family"] != "serve"]
    for r in v3["rows"]:
        r.pop("observables", None)
    assert bg.validate_gate_document(v3, accept=bg.KNOWN_SCHEMAS) == []
    assert bg.validate_gate_document(v3)
    # v3 rows must separate requested trials from the padded batch
    bad = copy.deepcopy(doc)
    bad["rows"][0]["n_pad"] = -1
    bad["rows"][0]["n_trials"] = 2
    assert any("n_pad" in e for e in bg.validate_gate_document(bad))
    # timing stats are mandatory and positive
    bad = copy.deepcopy(doc)
    del bad["rows"][0]["timing"]
    assert any("timing" in e for e in bg.validate_gate_document(bad))
    bad = copy.deepcopy(doc)
    bad["rows"][0]["timing"]["median_us"] = 0
    assert any("median_us" in e for e in bg.validate_gate_document(bad))
    # legacy v2 rows (conflated 'trials') no longer validate
    bad = copy.deepcopy(doc)
    del bad["rows"][0]["n_trials"]
    assert any("n_trials" in e for e in bg.validate_gate_document(bad))
    # dropping a kernel from coverage fails the document
    bad = copy.deepcopy(doc)
    bad["rows"] = [r for r in bad["rows"] if r["local_kernel"] != "fused"]
    assert any("fused" in e for e in bg.validate_gate_document(bad))


def test_gate_document_schema_v5_serve_row():
    """v5: current-schema documents must carry the serving throughput
    row, serve rows validate their own counters, and older schemas
    reject the family outright."""
    from benchmarks import bench_gate as bg
    doc = _gate_doc()
    assert bg.validate_gate_document(doc) == []
    # dropping the serve row fails a current-schema document
    bad = copy.deepcopy(doc)
    bad["rows"] = [r for r in bad["rows"] if r["family"] != "serve"]
    assert any("serve" in e for e in bg.validate_gate_document(bad))
    # serve counters are load-bearing: dropped requests fail the row
    bad = copy.deepcopy(doc)
    next(r for r in bad["rows"] if r["family"] == "serve")["dropped"] = 1
    assert any("dropped" in e for e in bg.validate_gate_document(bad))
    bad = copy.deepcopy(doc)
    next(r for r in bad["rows"]
         if r["family"] == "serve")["n_requests"] = 0
    assert any("n_requests" in e for e in bg.validate_gate_document(bad))
    # a serve row inside an older-schema document is a schema violation
    assert any("require schema" in e for e in bg.validate_gate_row(
        _serve_gate_row(), schema=bg.SCHEMA_V3))
    assert any("require schema" in e for e in bg.validate_gate_row(
        _serve_gate_row(), schema=bg.SCHEMA_V4))
    # the standalone row validates (the loadgen gate_row shape)
    assert bg.validate_gate_row(_serve_gate_row()) == []


def test_compare_documents_gates_regressions():
    from benchmarks import bench_gate as bg
    base = _gate_doc()
    # identical docs compare clean
    assert bg.compare_documents(copy.deepcopy(base), base, 0.5) == []
    # a >threshold updates_per_s drop on a matching row fails
    cand = copy.deepcopy(base)
    cand["rows"][0]["updates_per_s"] = base["rows"][0]["updates_per_s"] * 0.3
    failures = bg.compare_documents(cand, base, 0.5)
    assert len(failures) == 1 and cand["rows"][0]["name"] in failures[0]
    # ...but survives a generous threshold
    assert bg.compare_documents(cand, base, 0.75) == []
    # no matching (family, scenario, kernel, backend) keys at all: the
    # gate refuses to vacuously pass
    cand = copy.deepcopy(base)
    for r in cand["rows"]:
        r["backend"] = "tpu"
    assert any("compared nothing" in f
               for f in bg.compare_documents(cand, base, 0.5))
    # different smoke flags are incomparable, not regressions
    cand = copy.deepcopy(base)
    cand["smoke"] = False
    cand["rows"][0]["updates_per_s"] = 1.0
    assert bg.compare_documents(cand, base, 0.5) == []
    # an invalid baseline fails loudly
    assert bg.compare_documents(copy.deepcopy(base), {"schema": "nope"},
                                0.5)
    # nonsense thresholds are rejected
    assert bg.compare_documents(copy.deepcopy(base), base, 1.5)


def test_gate_cli_compare_exits_nonzero_on_regression(tmp_path,
                                                      monkeypatch):
    """The acceptance criterion: ``bench_gate --compare`` must exit
    non-zero on a synthetic regressed row — and append the candidate to
    the history trajectory BEFORE failing."""
    from benchmarks import bench_gate as bg
    base = _gate_doc()
    regressed = copy.deepcopy(base)
    for r in regressed["rows"]:
        r["updates_per_s"] = 1.0
    base_p = tmp_path / "baseline.json"
    cand_p = tmp_path / "cand.json"
    hist_p = tmp_path / "BENCH_history.jsonl"
    base_p.write_text(json.dumps(base))
    cand_p.write_text(json.dumps(regressed))

    argv = ["bench_gate", "--compare", str(base_p), "--candidate",
            str(cand_p), "--regressionThreshold", "0.75", "--history",
            str(hist_p)]
    monkeypatch.setattr(sys, "argv", argv)
    with pytest.raises(SystemExit) as exc:
        bg.main()
    assert exc.value.code == 1
    # the trajectory entry landed despite the failure, and validates
    assert bg.validate_file(str(hist_p)) == []
    assert json.loads(hist_p.read_text())["rows"][0]["updates_per_s"] == 1.0

    # the clean case passes and appends a second history line
    monkeypatch.setattr(
        sys, "argv",
        ["bench_gate", "--compare", str(base_p), "--candidate", str(base_p),
         "--regressionThreshold", "0.5", "--history", str(hist_p)])
    bg.main()
    assert len(hist_p.read_text().splitlines()) == 2
    assert bg.validate_file(str(hist_p)) == []


def test_validate_file_dispatches_history_and_rows(tmp_path):
    """validate_file must accept gate documents, history JSONL (one
    document per line) and plain BENCH_JSON row streams — and reject a
    malformed document embedded in a history line."""
    from benchmarks import bench_gate as bg
    doc = _gate_doc()
    hist = tmp_path / "hist.jsonl"
    hist.write_text(json.dumps(doc, separators=(",", ":")) + "\n"
                    + json.dumps(doc, separators=(",", ":")) + "\n")
    assert bg.validate_file(str(hist)) == []
    rows = tmp_path / "rows.jsonl"
    rows.write_text('{"name": "x", "us_per_call": 3.5, "derived": ""}\n')
    assert bg.validate_file(str(rows)) == []
    bad_doc = dict(doc, rows=[])
    bad = tmp_path / "bad.jsonl"
    bad.write_text(json.dumps(bad_doc, separators=(",", ":")) + "\n")
    assert bg.validate_file(str(bad))


@pytest.mark.slow
def test_trials_throughput_smoke_multi_device():
    """The pod / composed-mesh sweeps need >1 device to be meaningful —
    smoke them on 4 fake devices (covers the sharded_pod benchmark path)."""
    stdout = _run_smoke(
        "trials_throughput",
        extra_env={"XLA_FLAGS": "--xla_force_host_platform_device_count=4"})
    _assert_csv_rows("trials_throughput", stdout)
    assert "trials_composed_" in stdout, stdout
