"""Benchmark smoke tests: every benchmarks/*.py module runs end-to-end in
its tiny ``ESCG_BENCH_SMOKE=1`` configuration (benchmarks/common.py) and
emits at least one well-formed CSV row — benchmark code can never silently
rot behind the paper figures it reproduces (DESIGN.md §7)."""
import glob
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# roofline_table legitimately emits nothing without dry-run records; it
# must still exit cleanly
_MAY_BE_EMPTY = {"roofline_table"}

MODULES = sorted(
    os.path.basename(p)[:-3]
    for p in glob.glob(os.path.join(REPO, "benchmarks", "*.py"))
    if os.path.basename(p) not in ("common.py", "run.py", "__init__.py"))


def _run_smoke(module: str, extra_env=None) -> str:
    env = dict(os.environ)
    env["ESCG_BENCH_SMOKE"] = "1"
    env["PYTHONPATH"] = (os.path.join(REPO, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    env.update(extra_env or {})
    out = subprocess.run(
        [sys.executable, "-m", f"benchmarks.{module}"], cwd=REPO,
        capture_output=True, text=True, timeout=420, env=env)
    assert out.returncode == 0, (
        f"benchmarks.{module} smoke run failed:\nSTDOUT:\n{out.stdout}\n"
        f"STDERR:\n{out.stderr}")
    return out.stdout


def _assert_csv_rows(module: str, stdout: str) -> None:
    rows = [ln for ln in stdout.splitlines()
            if ln and not ln.startswith("#")]
    if module in _MAY_BE_EMPTY and not rows:
        return
    assert rows, f"benchmarks.{module} emitted no CSV rows:\n{stdout}"
    for ln in rows:
        parts = ln.split(",")
        assert len(parts) >= 2, f"malformed row from {module}: {ln!r}"
        float(parts[1])          # us_per_call must parse
        assert "ERROR" not in ln, f"benchmark errored: {ln!r}"


def test_modules_discovered():
    assert len(MODULES) >= 9, MODULES


@pytest.mark.slow
@pytest.mark.parametrize("module", MODULES)
def test_benchmark_smoke(module):
    _assert_csv_rows(module, _run_smoke(module))


@pytest.mark.slow
def test_trials_throughput_smoke_multi_device():
    """The pod / composed-mesh sweeps need >1 device to be meaningful —
    smoke them on 4 fake devices (covers the sharded_pod benchmark path)."""
    stdout = _run_smoke(
        "trials_throughput",
        extra_env={"XLA_FLAGS": "--xla_force_host_platform_device_count=4"})
    _assert_csv_rows("trials_throughput", stdout)
    assert "trials_composed_" in stdout, stdout
