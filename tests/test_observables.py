"""On-device observable pipelines (DESIGN.md §11): registry rails, ring
buffer semantics, obs-on/off bit-identity across the engine registry, the
shard_map density-count path, flush-schedule invariance and the unified
``RunResult`` protocol.

The central contract under test: every registered observable is a pure
grid/counts read evaluated inside the jitted chunk — it consumes no PRNG
state and never transfers per-MCS data to the host — so turning the
pipeline on or off leaves every trajectory bit-identical, for every
``(engine, local_kernel)`` pair the registry admits (including the
``k_mcs`` megakernel path, where grid-derived observables lag-hold at
launch-group boundaries).
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EscgParams, dominance as dm, engines, simulate
from repro.core import observables as obs
from repro.core.results import (RunResult, decode_observables,
                                encode_observables)
from repro.core.scenarios import (EngineConfig, RunConfig, make_scenario,
                                  scenario_observables)
from repro.core.simulation import SimResult
from repro.core.trials import TrialResult, run_trials

pytestmark = pytest.mark.composed   # re-run by the CI 8-fake-device job

H, W, TILE, SPECIES, N_MCS = 16, 32, (8, 16), 5, 6
OBS_ALL = obs.observable_names()


def _params(name: str, **overrides) -> EscgParams:
    kw = dict(length=W, height=H, species=SPECIES, mobility=1e-3,
              empty=0.1, seed=5, engine=name, tile=TILE, mcs=N_MCS,
              chunk_mcs=N_MCS)
    kw.update(overrides)
    return EscgParams(**kw).validate()


def _engine_kernel_pairs():
    return [(spec.name, lk)
            for spec in engines.engine_specs()
            for lk in (spec.caps.local_kernels or ("jnp",))]


def _dom():
    return dm.circulant(SPECIES, (1, 2))


# ------------------------------- registry ---------------------------------- #

def test_registry_contents_and_widths():
    assert set(OBS_ALL) == {"densities", "interface_length",
                            "cluster_size", "snapshot"}
    p = _params("batched", observables=OBS_ALL)
    widths = {s.name: s.width(p) for s in obs.observable_specs()}
    assert widths["densities"] == SPECIES + 1
    assert widths["interface_length"] == widths["cluster_size"] == 1
    assert widths["snapshot"] == 8 * 8      # min(8, H) * min(8, W)
    pipe = obs.build_pipeline(p)
    assert pipe.width == sum(widths.values())


def test_unknown_observable_rejected():
    with pytest.raises(ValueError, match="unknown observable"):
        obs.get_observable("nope")
    with pytest.raises(ValueError, match="unknown observable"):
        _params("batched", observables=("nope",))


def test_negative_capacity_rejected():
    with pytest.raises(ValueError, match="obs_capacity"):
        _params("batched", observables=("densities",), obs_capacity=-1)


def test_every_engine_gets_the_generic_observe_hook():
    """EngineCaps rails (DESIGN.md §11): the full registry is legal on
    every engine family, and ``engines.build`` attaches a non-None
    ``observe`` hook exactly when observables are requested."""
    dom_j = jnp.asarray(_dom(), jnp.float32)
    for name, lk in _engine_kernel_pairs():
        p_on = _params(name, local_kernel=lk, observables=OBS_ALL)
        p_off = _params(name, local_kernel=lk)
        assert engines.build(p_on, dom_j).observe is not None
        assert engines.build(p_off, dom_j).observe is None


# ---------------------------- numeric oracles ------------------------------ #

def test_observable_rows_match_numpy_oracles():
    """Each registered observable against an independent numpy
    implementation on a random lattice (raw device row + host post)."""
    p = _params("batched", observables=OBS_ALL)
    pipe = obs.build_pipeline(p)
    g_np = np.asarray(
        jax.random.randint(jax.random.PRNGKey(7), (H, W), 0, SPECIES + 1),
        np.int32)
    counts = np.bincount(g_np.ravel(), minlength=SPECIES + 1)
    row = np.asarray(pipe.row(jnp.asarray(g_np), jnp.asarray(counts)))
    streams = pipe.split(row[None])

    n = H * W
    np.testing.assert_allclose(streams["densities"][0], counts / n)
    unlike = (np.sum(g_np != np.roll(g_np, -1, axis=1))
              + np.sum(g_np != np.roll(g_np, -1, axis=0)))
    np.testing.assert_allclose(streams["interface_length"][0, 0],
                               unlike / (2.0 * n))
    like = sum(np.sum((g_np == np.roll(g_np, -1, axis=ax))
                      & (g_np > 0)) for ax in (1, 0))
    np.testing.assert_allclose(streams["cluster_size"][0, 0],
                               like / (2.0 * n))
    snap = streams["snapshot"][0]
    assert snap.shape == (8, 8)
    bh, bw = H // 8, W // 8
    block = g_np[:8 * bh, :8 * bw].reshape(8, bh, 8, bw)
    hist = np.stack([(block == s).sum(axis=(1, 3))
                     for s in range(SPECIES + 1)], axis=-1)
    np.testing.assert_array_equal(snap, np.argmax(hist, axis=-1))


# ------------------------------ ring buffer -------------------------------- #

def test_ring_push_wraparound():
    ring, pos = obs.ring_init(3, (2,))
    for i in range(7):
        ring, pos = obs.ring_push(ring, pos, jnp.full((2,), float(i)))
    assert int(pos) == 7
    # slots hold rows 4..6 at positions 4%3, 5%3, 6%3
    np.testing.assert_array_equal(np.asarray(ring)[:, 0], [6.0, 4.0, 5.0])


def test_ring_push_many_matches_single_pushes():
    rows = jnp.arange(10, dtype=jnp.float32).reshape(5, 2)
    ring_a, pos_a = obs.ring_init(4, (2,))
    ring_a, pos_a = obs.ring_push_many(ring_a, pos_a, rows)
    ring_b, pos_b = obs.ring_init(4, (2,))
    for r in rows:
        ring_b, pos_b = obs.ring_push(ring_b, pos_b, r)
    assert int(pos_a) == int(pos_b) == 5
    np.testing.assert_array_equal(np.asarray(ring_a), np.asarray(ring_b))


def test_ring_flush_ordering_and_lossy_wraparound():
    ring, pos = obs.ring_init(4, (1,))
    for i in range(6):
        ring, pos = obs.ring_push(ring, pos, jnp.full((1,), float(i)))
    buf = np.asarray(ring)
    # a window that fits returns rows in push order
    np.testing.assert_array_equal(obs.ring_flush(buf, 2, 6)[:, 0],
                                  [2, 3, 4, 5])
    # a window wider than the capacity keeps only the newest rows
    np.testing.assert_array_equal(obs.ring_flush(buf, 0, 6)[:, 0],
                                  [2, 3, 4, 5])
    # empty window
    assert obs.ring_flush(buf, 6, 6).shape == (0, 1)


def test_simulate_rejects_undersized_ring():
    p = _params("batched", observables=("densities",), obs_capacity=2,
                mcs=N_MCS, chunk_mcs=N_MCS)
    with pytest.raises(ValueError, match="obs_capacity"):
        simulate(p, _dom(), stop_on_stasis=False)


# -------------------- bit-identity across the registry --------------------- #

@pytest.mark.parametrize("name,local_kernel", _engine_kernel_pairs())
def test_simulate_obs_on_off_bit_identity(name, local_kernel):
    """The tentpole contract: streaming the full observable registry
    leaves the dynamics bit-identical for every (engine, local_kernel)
    pair — observe consumes no PRNG state, by construction."""
    p_off = _params(name, local_kernel=local_kernel)
    p_on = _params(name, local_kernel=local_kernel, observables=OBS_ALL)
    r_off = simulate(p_off, _dom(), stop_on_stasis=False)
    r_on = simulate(p_on, _dom(), stop_on_stasis=False)
    np.testing.assert_array_equal(r_on.grid, r_off.grid)
    np.testing.assert_array_equal(r_on.densities, r_off.densities)
    assert r_on.mcs_completed == r_off.mcs_completed
    # per-MCS cadence: densities carry the extra MCS-0 row (the legacy
    # densities trace), grid-derived streams start at MCS 1
    assert r_on.observables["densities"].shape[0] == N_MCS + 1
    for nm in set(OBS_ALL) - {"densities"}:
        assert r_on.observables[nm].shape[0] == N_MCS
    assert set(r_off.observables) == {"densities"}


@pytest.mark.parametrize("name,local_kernel,k_mcs", [
    ("pallas_fused", "jnp", 3), ("sharded", "fused", 3),
    ("sharded_pod", "fused", 2)])
def test_k_mcs_obs_bit_identity_and_lag_hold(name, local_kernel, k_mcs):
    """Megakernel launches bank per-MCS counts but hide intermediate
    grids: count-derived observables keep per-MCS cadence, grid-derived
    ones lag-hold at launch-group boundaries — dynamics stay
    bit-identical obs on/off."""
    kw = dict(local_kernel=local_kernel, k_mcs=k_mcs, mcs=N_MCS,
              chunk_mcs=N_MCS)
    r_off = simulate(_params(name, **kw), _dom(), stop_on_stasis=False)
    r_on = simulate(_params(name, observables=OBS_ALL, **kw), _dom(),
                    stop_on_stasis=False)
    np.testing.assert_array_equal(r_on.grid, r_off.grid)
    np.testing.assert_array_equal(r_on.densities, r_off.densities)
    # densities stream from banked counts: exact per-MCS values
    np.testing.assert_allclose(r_on.observables["densities"][1:],
                               r_off.densities[1:])
    # grid-derived streams repeat within each launch group (lag-hold)
    iface = r_on.observables["interface_length"][:, 0]
    assert len(iface) == N_MCS
    for start in range(0, N_MCS - k_mcs + 1, k_mcs):
        group = iface[start:start + k_mcs]
        assert np.all(group == group[0])


def test_obs_capacity_sweep_is_invariant():
    """Any capacity >= the chunk length reconstructs the identical
    streams (the ring is an implementation detail, not a window)."""
    base = None
    for cap in (0, N_MCS, N_MCS + 3, 4 * N_MCS):
        p = _params("batched", observables=("densities",
                                            "interface_length"),
                    obs_capacity=cap)
        r = simulate(p, _dom(), stop_on_stasis=False)
        if base is None:
            base = r.observables
        else:
            for nm, v in r.observables.items():
                np.testing.assert_array_equal(v, base[nm])


# ------------------------------ trial driver ------------------------------- #

def test_run_trials_obs_on_off_and_flush_schedule_invariance():
    """Trial statistics are bit-identical obs on/off, and the observable
    streams are invariant to the flush schedule: chunk length and
    async_stats change when/how the ring is flushed, never what it
    holds."""
    p_off = _params("batched", mcs=12, chunk_mcs=12)
    r_off = run_trials(p_off, _dom(), n_trials=3, stop_on_stasis=False)
    base = None
    for chunk, async_stats in ((12, True), (4, True), (4, False),
                               (5, True)):
        p = _params("batched", observables=("densities",
                                            "interface_length"),
                    mcs=12, chunk_mcs=chunk)
        r = run_trials(p, _dom(), n_trials=3, stop_on_stasis=False,
                       async_stats=async_stats)
        np.testing.assert_array_equal(r.survival, r_off.survival)
        np.testing.assert_array_equal(r.densities, r_off.densities)
        np.testing.assert_array_equal(r.stasis_mcs, r_off.stasis_mcs)
        assert r.observables["densities"].shape == (3, 12, SPECIES + 1)
        if base is None:
            base = r.observables
        else:
            for nm, v in r.observables.items():
                np.testing.assert_array_equal(v, base[nm],
                                              err_msg=f"{nm} chunk={chunk} "
                                                      f"async={async_stats}")


def test_run_trials_obs_early_exit_truncates_streams():
    """A stasis early-exit stops the stream at mcs_completed: the
    speculative in-flight chunk is never flushed, and async/sync
    schedules agree exactly."""
    # S=2 cyclic: one species eats the other; an 8x8 lattice reaches
    # stasis (<= 1 species alive) well before the MCS budget
    kw = dict(length=8, height=8, species=2, mobility=1e-2, empty=0.0,
              seed=3, engine="batched", mcs=2000, chunk_mcs=25,
              observables=("densities",))
    p = EscgParams(**kw).validate()
    dom2 = dm.circulant(2, (1,))
    r_async = run_trials(p, dom2, n_trials=2, stop_on_stasis=True,
                         async_stats=True)
    r_sync = run_trials(p, dom2, n_trials=2, stop_on_stasis=True,
                        async_stats=False)
    assert r_async.mcs_completed < 2000, "expected a stasis early-exit"
    assert r_async.mcs_completed == r_sync.mcs_completed
    assert (r_async.observables["densities"].shape
            == (2, r_async.mcs_completed, 3))
    np.testing.assert_array_equal(r_async.observables["densities"],
                                  r_sync.observables["densities"])
    np.testing.assert_array_equal(r_async.stasis_mcs, r_sync.stasis_mcs)


# ---------------------- sharded density-count path ------------------------- #

def test_density_counts_sharded_matches_ref_on_8_devices(subproc):
    """kernels.density_counts under shard_map + psum on a 2x4 mesh is
    bit-identical to the bincount oracle on the gathered lattice."""
    subproc("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.kernels.density import density_counts_sharded
        from repro.kernels.ref import density_ref
        assert jax.device_count() == 8
        mesh = Mesh(np.array(jax.devices()).reshape(2, 4),
                    ("rows", "cols"))
        grid = jax.random.randint(jax.random.PRNGKey(0), (16, 32), 0, 6,
                                  dtype=jnp.int32)
        grid = jax.device_put(grid, NamedSharding(mesh, P("rows", "cols")))
        got = jax.jit(lambda g: density_counts_sharded(
            g, 5, mesh, interpret=True))(grid)
        want = density_ref(np.asarray(grid), 5)
        np.testing.assert_array_equal(np.asarray(got), want)
        print("OK")
        """, 8)


# --------------------------- scenario integration -------------------------- #

def test_scenario_observables_intersects_registry():
    assert scenario_observables("park3") == ("densities",
                                             "interface_length")
    # caps also declare result-level statistics that are NOT streaming
    # observables — they must never leak into the pipeline selection
    for name in ("zhong_density", "nspecies5"):
        for nm in scenario_observables(name):
            assert nm in OBS_ALL
    assert scenario_observables("no_such_scenario") == ()


def test_scenario_first_autofill_and_explicit_off():
    sc = make_scenario("park3")
    eng = EngineConfig(engine="batched")
    run = RunConfig(length=W, height=H, mcs=N_MCS, chunk_mcs=N_MCS, seed=2)
    r_auto = simulate(sc, engine=eng, run=run, stop_on_stasis=False)
    assert set(r_auto.observables) == {"densities", "interface_length"}
    r_off = simulate(sc, engine=eng, run=run.replace(observables=()),
                     stop_on_stasis=False)
    assert set(r_off.observables) == {"densities"}
    np.testing.assert_array_equal(r_auto.grid, r_off.grid)


def test_legacy_positional_params_deprecated():
    p = _params("batched")
    with pytest.warns(DeprecationWarning, match="[Ss]cenario"):
        simulate(p, _dom(), stop_on_stasis=False)
    with pytest.warns(DeprecationWarning, match="[Ss]cenario"):
        run_trials(p, _dom(), n_trials=1, stop_on_stasis=False)
    sc = make_scenario("park3")
    with pytest.raises(TypeError):
        simulate(sc, engine_config=EngineConfig(),
                 engine=EngineConfig(), stop_on_stasis=False)


# ----------------------------- RunResult API ------------------------------- #

def test_runresult_protocol_and_json_round_trip():
    p = _params("batched", observables=("densities", "snapshot"))
    res = simulate(p, _dom(), stop_on_stasis=False)
    tr = run_trials(p, _dom(), n_trials=2, stop_on_stasis=False)
    for r in (res, tr):
        assert isinstance(r, RunResult)
        assert r.mcs_completed == N_MCS
        assert set(r.observables) >= {"densities", "snapshot"}

    back = SimResult.from_json(res.to_json())
    np.testing.assert_array_equal(back.grid, res.grid)
    assert back.grid.dtype == res.grid.dtype
    for nm, v in res.observables.items():
        np.testing.assert_array_equal(back.observables[nm], v)
        assert back.observables[nm].dtype == v.dtype
    np.testing.assert_array_equal(back.densities, res.densities)

    tback = TrialResult.from_json(tr.to_json())
    for nm, v in tr.observables.items():
        np.testing.assert_array_equal(tback.observables[nm], v)
    np.testing.assert_array_equal(tback.survival, tr.survival)
    # pre-observables documents still load (the field defaults empty)
    d = json.loads(tr.to_json())
    del d["observables"]
    legacy = TrialResult.from_json(json.dumps(d))
    assert legacy.observables == {}


def test_encode_decode_observables_inverse():
    payload = {"a": np.arange(6, dtype=np.float64).reshape(2, 3),
               "b": np.zeros((0, 1), np.float32)}
    back = decode_observables(encode_observables(payload))
    for nm, v in payload.items():
        np.testing.assert_array_equal(back[nm], v)
        assert back[nm].dtype == v.dtype
