"""Science replication tests — the paper's validation claims at reduced
scale (full-scale replications live in benchmarks/ and examples/).

Claims exercised:
  * Zhong et al. ablated RPSLS: the Paper species goes extinct early
    (paper: 200-600 MCS at L=200; faster on smaller lattices).
  * RMF: three-species coexistence below the mobility threshold.
  * Park et al.: probabilistic-rate model runs and produces survival
    statistics; mobility extension (companion paper) changes dynamics.
"""
import numpy as np
import pytest

from repro.core import EscgParams, dominance as dm, metrics, simulate
from repro.core.park import park_params, survival_probabilities


@pytest.mark.slow
def test_zhong_paper_species_extinct_early():
    p = EscgParams(length=64, height=64, species=5, mobility=1e-4,
                   mcs=1500, chunk_mcs=250, engine="batched", seed=11)
    res = simulate(p, dm.zhong_ablated_rpsls(), stop_on_stasis=False)
    ext = metrics.first_extinction_mcs(res.densities, dm.PAPER)
    assert 0 < ext <= 1500, f"Paper should die early, got {ext}"
    # the two sub-cycles persist at this horizon: >=3 species alive
    alive = (res.densities[-1][1:] > 0).sum()
    assert alive >= 3


@pytest.mark.slow
def test_rmf_coexistence_low_mobility():
    p = EscgParams(length=64, height=64, species=3, mobility=3e-5,
                   empty=0.1, mcs=300, chunk_mcs=100, engine="batched",
                   seed=5)
    res = simulate(p, dm.RPS(), stop_on_stasis=False)
    assert (res.densities[-1][1:] > 0.05).all(), res.densities[-1]


@pytest.mark.slow
def test_sublattice_engine_reproduces_zhong_extinction():
    """The TPU-native engine shows the same qualitative science."""
    p = EscgParams(length=64, height=64, species=5, mobility=1e-4,
                   mcs=1500, chunk_mcs=250, engine="sublattice",
                   tile=(8, 16), seed=11)
    res = simulate(p, dm.zhong_ablated_rpsls(), stop_on_stasis=False)
    ext = metrics.first_extinction_mcs(res.densities, dm.PAPER)
    assert 0 < ext <= 1500


@pytest.mark.slow
def test_park_model_survival_statistics():
    ps, hist = survival_probabilities(alpha=0.3, beta=0.75, gamma=1.0,
                                      L=24, n_trials=4, mcs=150)
    assert ps.shape == (8,)
    assert hist.shape == (9,)
    assert abs(hist.sum() - 1.0) < 1e-6
    assert (0 <= ps).all() and (ps <= 1).all()


def test_park_params_match_paper_protocol():
    p = park_params(L=100)
    assert p.species == 8
    assert p.mcs == 100 * 100            # terminate after L^2 MCS
    assert p.eps == 0.0                  # no mobility in Park et al.
    p2 = park_params(L=50, mobility=1e-4)
    assert p2.eps > 0.0                  # the companion-paper extension
