"""Synthetic data pipeline: determinism, restart-reproducibility, label
alignment, learnable structure."""
import jax
import numpy as np

from repro.configs import ARCHS
from repro.configs.base import ShapeConfig
from repro.data.synthetic import SyntheticTokens, batch_for_model
from repro.models import build_model


def test_deterministic_per_step():
    st = SyntheticTokens(vocab=128, seq_len=64, batch=4, seed=3)
    a = st.batch_at(17)
    b = st.batch_at(17)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))
    c = st.batch_at(18)
    assert not np.array_equal(np.asarray(a["tokens"]),
                              np.asarray(c["tokens"]))


def test_labels_are_next_tokens():
    st = SyntheticTokens(vocab=128, seq_len=64, batch=4, seed=0)
    b = st.batch_at(0)
    np.testing.assert_array_equal(np.asarray(b["labels"])[:, :-1],
                                  np.asarray(b["tokens"])[:, 1:])


def test_periodic_structure_present():
    st = SyntheticTokens(vocab=1024, seq_len=64, batch=8, seed=1,
                         structure=1.0)
    t = np.asarray(st.batch_at(0)["tokens"])
    p = SyntheticTokens.PERIOD
    np.testing.assert_array_equal(t[:, p:], t[:, :-p])
    # with structure=0 the stream is iid noise (no exact periodicity)
    st0 = SyntheticTokens(vocab=1024, seq_len=64, batch=8, seed=1,
                          structure=0.0)
    t0 = np.asarray(st0.batch_at(0)["tokens"])
    assert (t0[:, p:] == t0[:, :-p]).mean() < 0.05


def test_tokens_in_vocab_range():
    st = SyntheticTokens(vocab=37, seq_len=50, batch=3, seed=2)
    b = st.batch_at(5)
    for k in ("tokens", "labels"):
        arr = np.asarray(b[k])
        assert arr.min() >= 0 and arr.max() < 37


def test_batch_for_model_covers_modalities():
    key = jax.random.PRNGKey(0)
    for arch in ("whisper-small", "pixtral-12b", "granite-3-8b"):
        model = build_model(ARCHS[arch].reduced())
        b = batch_for_model(model, ShapeConfig("t", 16, 2, "train"), 0)
        specs = model.input_specs(ShapeConfig("t", 16, 2, "train"))
        assert set(b) == set(specs), arch
        for k, v in b.items():
            assert v.shape == specs[k].shape, (arch, k)
