"""Registry-driven cross-engine equivalence suite (DESIGN.md §2/§6).

Every test in this module parametrizes over the registry's
``(engine, local_kernel)`` pairs — new engines AND new local kernels are
covered the moment they register, with zero test edits:

* every engine must run through ``simulate`` deterministically and
  conserve cell counts;
* every pair declaring an oracle (``EngineCaps.oracle_for``) must be
  bit-identical to it at the one-MCS level (grids, kept, attempts) — this
  is how ``pallas``/``sharded``/``sharded_pod`` inherit the ``sublattice``
  trajectory guarantee, and how the sharded engines'
  ``local_kernel='fused'`` path inherits the SECOND oracle family,
  ``pallas_fused`` (in-kernel Philox counters, ``equiv_oracles``);
* engines the trial driver accepts (vmappable or pod-composable) must
  produce bit-identical ``run_trials`` statistics to their oracle's
  vmapped path.

Runs on whatever devices the process has: on one CPU device the
multi-device engines collapse to 1x1 layouts; under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI
composed-mesh job) the same assertions exercise real multi-device
placement — bit-identity for ANY layout is exactly the invariant under
test.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EscgParams, dominance as dm, engines, simulate
from repro.core.lattice import init_grid
from repro.core.trials import run_trials

pytestmark = pytest.mark.composed   # re-run by the CI 8-fake-device job

H, W, TILE, SPECIES, N_MCS = 16, 32, (8, 16), 5, 3


def _params(name: str, **overrides) -> EscgParams:
    kw = dict(length=W, height=H, species=SPECIES, mobility=1e-3,
              empty=0.1, seed=5, engine=name, tile=TILE, mcs=N_MCS,
              chunk_mcs=N_MCS)
    kw.update(overrides)
    return EscgParams(**kw).validate()


def _engine_kernel_pairs():
    """Every (engine, local_kernel) combination the registry admits —
    engines that ignore the knob contribute one 'jnp' row."""
    return [(spec.name, lk)
            for spec in engines.engine_specs()
            for lk in (spec.caps.local_kernels or ("jnp",))]


def _dom():
    return dm.circulant(SPECIES, (1, 2))


@functools.lru_cache(maxsize=None)
def _oracle_trajectory(name: str):
    """Oracle-side trajectory, cached per engine name — several
    (engine, local_kernel) pairs answer to the same oracle (sublattice,
    pallas_fused) and need not recompute it."""
    return _mcs_trajectory(_params(name))


@functools.lru_cache(maxsize=None)
def _oracle_trials(name: str):
    """Oracle-side run_trials statistics, cached per engine name."""
    return run_trials(_params(name), _dom(), n_trials=3, n_mcs=N_MCS,
                      stop_on_stasis=False)


def _mcs_trajectory(p: EscgParams, n_mcs: int = N_MCS):
    """(grids, kepts, attempts) per MCS from the built engine, driven with
    the same fold-in key schedule for every engine."""
    dom_j = jnp.asarray(_dom(), jnp.float32)
    eng = engines.build(p, dom_j)
    key = jax.random.PRNGKey(p.seed)
    key, k0 = jax.random.split(key)
    grid = init_grid(k0, p.height, p.length, p.species, p.empty)
    if eng.grid_sharding is not None:
        grid = jax.device_put(grid, eng.grid_sharding)
    grids, kepts, atts = [], [], []
    for i in range(n_mcs):
        grid, kept, att = eng.one_mcs(grid, jax.random.fold_in(key, i))
        grids.append(np.asarray(grid))
        kepts.append(int(kept))
        atts.append(int(att))
    return grids, kepts, atts


@pytest.mark.parametrize("name", engines.engine_names())
def test_engine_is_deterministic_and_conserves_cells(name):
    """Same params + key -> bit-identical trajectory across two
    independent builds; every MCS conserves the cell count."""
    p = _params(name)
    r1 = simulate(p, _dom(), stop_on_stasis=False)
    r2 = simulate(p, _dom(), stop_on_stasis=False)
    np.testing.assert_array_equal(r1.grid, r2.grid)
    np.testing.assert_array_equal(r1.densities, r2.densities)
    np.testing.assert_allclose(r1.densities.sum(axis=1), 1.0, atol=1e-6)
    assert r1.mcs_completed == N_MCS


@pytest.mark.parametrize("name,local_kernel", _engine_kernel_pairs())
def test_engine_matches_declared_oracle(name, local_kernel):
    """caps.oracle_for(local_kernel) is a bit-identity CONTRACT: same key,
    same grids/kept/attempts every MCS. The jnp/pallas kernels answer to
    ``sublattice``; the fused kernel answers to ``pallas_fused`` (its own
    PRNG family, ``equiv_oracles``). Pairs without an oracle (the oracles
    themselves) skip."""
    oracle = engines.get_engine(name).caps.oracle_for(local_kernel)
    if oracle is None:
        pytest.skip(f"engine {name!r} declares no equivalence oracle")
    g_a, k_a, t_a = _mcs_trajectory(_params(name, local_kernel=local_kernel))
    g_b, k_b, t_b = _oracle_trajectory(oracle)
    assert k_a == k_b and t_a == t_b
    for i, (ga, gb) in enumerate(zip(g_a, g_b)):
        np.testing.assert_array_equal(ga, gb, err_msg=f"MCS {i + 1}")


@pytest.mark.parametrize("name,local_kernel", _engine_kernel_pairs())
def test_trial_driver_matches_oracle(name, local_kernel):
    """run_trials statistics are bit-identical to the oracle engine's
    trial batch — covers the vmapped path (e.g. pallas) AND the composed
    pod x grid path (sharded_pod, every local kernel) with one
    assertion."""
    spec = engines.get_engine(name)
    if not (spec.caps.vmappable or spec.caps.pod_composable):
        pytest.skip(f"engine {name!r} cannot run trial batches")
    oracle = spec.caps.oracle_for(local_kernel)
    if oracle is None:
        pytest.skip(f"engine {name!r} declares no equivalence oracle")
    r = run_trials(_params(name, local_kernel=local_kernel), _dom(),
                   n_trials=3, n_mcs=N_MCS, stop_on_stasis=False)
    ro = _oracle_trials(oracle)
    np.testing.assert_array_equal(r.survival, ro.survival)
    np.testing.assert_array_equal(r.densities, ro.densities)
    np.testing.assert_array_equal(r.stasis_mcs, ro.stasis_mcs)
    np.testing.assert_array_equal(r.extinction_mcs, ro.extinction_mcs)


def _multi_mcs_pairs():
    """Every (engine, local_kernel) pair whose caps admit k_mcs > 1 —
    registry-driven, so a new megakernel-capable engine is covered the
    moment it registers. Engines with a local-kernel knob must run the
    'fused' kernel (validate_params enforces it)."""
    return [(spec.name, "fused" if spec.caps.local_kernels else "jnp")
            for spec in engines.engine_specs() if spec.caps.multi_mcs]


@pytest.mark.parametrize("name,local_kernel", _multi_mcs_pairs())
@pytest.mark.parametrize("k_mcs", [2, 3])
def test_k_mcs_bit_identical_to_single_step(name, local_kernel, k_mcs):
    """The multi-MCS megakernel contract (DESIGN.md §6): k_mcs is a pure
    launch-granularity knob. With N_MCS=3, k_mcs=2 exercises the grouped
    scan PLUS the remainder launch and k_mcs=3 the exact-multiple path —
    grids and the per-MCS density stream must match k_mcs=1 bit-for-bit."""
    base = simulate(_params(name, local_kernel=local_kernel), _dom(),
                    stop_on_stasis=False)
    r = simulate(_params(name, local_kernel=local_kernel, k_mcs=k_mcs),
                 _dom(), stop_on_stasis=False)
    np.testing.assert_array_equal(r.grid, base.grid)
    np.testing.assert_array_equal(r.densities, base.densities)
    assert r.mcs_completed == base.mcs_completed


@pytest.mark.parametrize("name,local_kernel", _multi_mcs_pairs())
def test_k_mcs_trial_driver_bit_identical(name, local_kernel):
    """run_trials statistics under k_mcs>1 match the k_mcs=1 run of the
    SAME engine — covers the vmapped grouped path (pallas_fused) and the
    composed multi_mcs_batch path (sharded_pod) with one assertion."""
    spec = engines.get_engine(name)
    if not (spec.caps.vmappable or spec.caps.pod_composable):
        pytest.skip(f"engine {name!r} cannot run trial batches")
    base = run_trials(_params(name, local_kernel=local_kernel), _dom(),
                      n_trials=3, n_mcs=N_MCS, stop_on_stasis=False)
    r = run_trials(_params(name, local_kernel=local_kernel, k_mcs=2),
                   _dom(), n_trials=3, n_mcs=N_MCS, stop_on_stasis=False)
    np.testing.assert_array_equal(r.survival, base.survival)
    np.testing.assert_array_equal(r.densities, base.densities)
    np.testing.assert_array_equal(r.stasis_mcs, base.stasis_mcs)
    np.testing.assert_array_equal(r.extinction_mcs, base.extinction_mcs)


def _reflecting_engines():
    """Every engine that supports reflecting (flux=False) boundaries —
    registry-driven, so a new boundary-agnostic engine is covered the
    moment it registers."""
    return [spec.name for spec in engines.engine_specs()
            if not spec.caps.flux_only]


@pytest.mark.parametrize("name", _reflecting_engines())
def test_reflecting_boundaries_deterministic_and_conserving(name):
    """flux=False (reflecting walls) is a first-class scenario boundary
    (Scenario.boundary='reflect', DESIGN.md §10): every engine whose caps
    admit it must run reflecting runs deterministically and conserve the
    cell count."""
    p = _params(name, flux=False)
    r1 = simulate(p, _dom(), stop_on_stasis=False)
    r2 = simulate(p, _dom(), stop_on_stasis=False)
    np.testing.assert_array_equal(r1.grid, r2.grid)
    np.testing.assert_array_equal(r1.densities, r2.densities)
    np.testing.assert_allclose(r1.densities.sum(axis=1), 1.0, atol=1e-6)
    assert r1.mcs_completed == N_MCS


@pytest.mark.parametrize("name", _reflecting_engines())
def test_reflecting_boundaries_change_the_trajectory(name):
    """flux must actually matter: reflecting walls break the torus, so
    the trajectory differs from the periodic run of the same seed — a
    silently ignored boundary flag would pass the determinism test."""
    r_flux = simulate(_params(name, flux=True), _dom(),
                      stop_on_stasis=False)
    r_refl = simulate(_params(name, flux=False), _dom(),
                      stop_on_stasis=False)
    assert not np.array_equal(r_flux.grid, r_refl.grid)


@pytest.mark.parametrize("name", _reflecting_engines())
def test_reflecting_trial_driver(name):
    """run_trials accepts reflecting boundaries on every engine that
    supports them (vmappable ones), with reproducible statistics."""
    spec = engines.get_engine(name)
    if not (spec.caps.vmappable or spec.caps.pod_composable):
        pytest.skip(f"engine {name!r} cannot run trial batches")
    kw = dict(n_trials=2, n_mcs=2, stop_on_stasis=False)
    r1 = run_trials(_params(name, flux=False), _dom(), **kw)
    r2 = run_trials(_params(name, flux=False), _dom(), **kw)
    np.testing.assert_array_equal(r1.survival, r2.survival)
    np.testing.assert_array_equal(r1.densities, r2.densities)


def test_every_oracle_is_registered():
    """Every oracle name — kernel-independent equiv_oracle AND the
    per-local-kernel equiv_oracles overrides — must resolve; a typo would
    silently skip the equivalence tests above. Override keys must be
    local kernels the engine actually accepts."""
    for spec in engines.engine_specs():
        oracles = [spec.caps.equiv_oracle] + [o for _, o in
                                              spec.caps.equiv_oracles]
        for oracle in oracles:
            if oracle is None:
                continue
            assert oracle in engines.engine_names(), \
                f"{spec.name} declares unknown oracle {oracle}"
            assert oracle != spec.name
        for lk, _ in spec.caps.equiv_oracles:
            assert lk in spec.caps.local_kernels, \
                (f"{spec.name} maps oracle for local kernel {lk!r} it "
                 f"does not accept ({spec.caps.local_kernels})")
