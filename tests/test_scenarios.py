"""Scenario layer (DESIGN.md §10): registry, composition, back-compat
facade parity, CLI resolution and the nspecies relabeling symmetry.

The load-bearing guarantees:

* decomposing the config API must not move a single bit — ``park3``
  composed through the legacy ``EscgParams`` facade reproduces the
  checked-in pre-redesign golden trajectory exactly;
* every registered scenario must run end-to-end through the CLI
  ``--scenario`` path on the vmapped (``batched``), tiled
  (``sublattice``) and composed-mesh (``sharded_pod``) engines — the
  acceptance criterion of the redesign;
* ``compose``/``decompose`` and every config dataclass JSON round-trip.
"""
import dataclasses
import hashlib
import json
import os

import jax
import numpy as np
import pytest

from repro.core import EscgParams, dominance as dm, engines, lattice
from repro.core import scenarios as sc_mod
from repro.core.scenarios import (EngineConfig, RunConfig, Scenario,
                                  compose, decompose, make_scenario,
                                  scenario_names)
from repro.core.simulation import simulate
from repro.core.trials import run_trials
from repro.launch.escg_run import build_parser, scenario_setup

GOLDEN = os.path.join(os.path.dirname(os.path.abspath(__file__)), "golden",
                      "reference_trajectory.json")

PRESETS = ("park3", "zhong_density", "nspecies5", "probabilistic",
           "asym_rps")


# ------------------------------- registry --------------------------------- #

def test_presets_registered():
    names = scenario_names()
    for name in ("park3", "zhong_density", "nspecies", "probabilistic",
                 "asym_rps"):
        assert name in names, name


def test_parametric_suffix_resolution():
    sc = make_scenario("nspecies7")
    assert sc.species == 7 and sc.name == "nspecies7"
    assert make_scenario("nspecies", S=7) == sc
    with pytest.raises(ValueError, match="unknown scenario"):
        make_scenario("park9")          # park3 is fixed, not parametric
    with pytest.raises(ValueError, match="unknown scenario"):
        make_scenario("no_such_scenario")


def test_builder_knobs_route_to_builder():
    """Overrides the builder declares keep preset-internal coupling:
    Park's mobility knob flips epsilon between 0 (no migration) and the
    2*M*N paper default."""
    assert make_scenario("probabilistic").epsilon == 0.0
    sc = make_scenario("probabilistic", mobility=1e-4)
    assert sc.epsilon is None and sc.mobility == 1e-4
    assert make_scenario("probabilistic", alpha=0.3).extra("alpha") == 0.3
    with pytest.raises(ValueError, match="accepts builder knobs"):
        make_scenario("park3", alpha=0.3)


def test_fixed_species_cannot_be_overridden():
    with pytest.raises(ValueError, match="fixed 8-species"):
        make_scenario("probabilistic", species=5)


def test_scenario_dominance_matches_study_networks():
    np.testing.assert_array_equal(make_scenario("park3").dominance(),
                                  dm.RPS())
    np.testing.assert_array_equal(
        make_scenario("zhong_density").dominance(), dm.zhong_ablated_rpsls())
    np.testing.assert_array_equal(
        make_scenario("nspecies7").dominance(), dm.circulant(7, (1, 2)))
    np.testing.assert_array_equal(
        make_scenario("nspecies3").dominance(), dm.circulant(3, (1,)))
    np.testing.assert_array_equal(
        make_scenario("probabilistic", alpha=0.2, beta=0.6).dominance(),
        dm.park_alliance_network(0.2, 0.6, 1.0))
    d = make_scenario("asym_rps").dominance()
    assert d[1, 2] == 1.0 and np.isclose(d[2, 3], 0.7) \
        and np.isclose(d[3, 1], 0.4)
    # ad-hoc scenarios fall back to the legacy circulant default
    np.testing.assert_array_equal(Scenario(species=4).dominance(),
                                  dm.circulant(4))


# ------------------------- JSON / composition ------------------------------ #

def test_config_json_round_trips():
    sc = make_scenario("probabilistic", alpha=0.3, beta=0.6, gamma=0.9)
    assert Scenario.from_json(sc.to_json()) == sc
    eng = EngineConfig(engine="sharded_pod", tile=(8, 16),
                       mesh_shape=(2, 1, 2), local_kernel="fused")
    assert EngineConfig.from_json(eng.to_json()) == eng
    run = RunConfig(length=64, height=32, mcs=123, seed=9, save=True)
    assert RunConfig.from_json(run.to_json()) == run
    # a round-tripped scenario rebuilds its dominance from the registry
    rt = Scenario.from_json(sc.to_json())
    np.testing.assert_array_equal(rt.dominance(), sc.dominance())


@pytest.mark.parametrize("name", PRESETS)
def test_compose_decompose_round_trip(name):
    p = compose(make_scenario(name), EngineConfig(tile=(8, 16)),
                RunConfig(length=32, height=16, mcs=7, seed=3))
    sc, eng, run = decompose(p, name=name)
    assert compose(sc, eng, run) == p
    assert EscgParams.from_scenario(*p.to_scenario(name=name)) == p


def test_reflecting_scenario_on_flux_only_engine_names_both():
    sc = make_scenario("park3", boundary="reflect")
    with pytest.raises(ValueError) as ei:
        compose(sc, EngineConfig(engine="sublattice"))
    msg = str(ei.value)
    assert "park3" in msg and "sublattice" in msg and "reflect" in msg
    # boundary-agnostic engines accept the same scenario
    assert compose(sc, EngineConfig(engine="batched")).flux is False


def test_resolve_config_rejects_configs_with_flat_params():
    with pytest.raises(ValueError, match="only apply"):
        sc_mod.resolve_config(EscgParams(), engine_config=EngineConfig())


# --------------------------- facade parity --------------------------------- #

def _grid_hash(grid: np.ndarray) -> str:
    return hashlib.sha256(
        np.ascontiguousarray(grid.astype("<i4")).tobytes()).hexdigest()


def test_park3_facade_bit_identical_to_pre_redesign_golden():
    """THE back-compat guarantee: park3 composed through the scenario
    layer reproduces byte-for-byte the flat-EscgParams golden trajectory
    recorded before the redesign (tests/golden/, unregenerated)."""
    with open(GOLDEN) as f:
        want = json.load(f)
    sc = make_scenario("park3", mobility=1e-3, empty=0.1)
    p = EscgParams.from_scenario(
        sc, EngineConfig(engine="reference"),
        RunConfig(length=12, height=12, mcs=5, chunk_mcs=1, seed=42))
    # the facade composes to exactly the frozen pre-redesign params ...
    assert json.loads(p.to_json()) == want["params"]
    # ... and the scenario-first driver path replays the frozen trajectory
    res = simulate(sc, engine_config=EngineConfig(engine="reference"),
                   run_config=RunConfig(length=12, height=12, mcs=5,
                                        chunk_mcs=1, seed=42),
                   stop_on_stasis=False)
    assert _grid_hash(res.grid) == want["final_hash"]
    np.testing.assert_array_equal(res.densities,
                                  np.asarray(want["densities"]))


def test_scenario_and_flat_params_drivers_bit_identical():
    """simulate(Scenario) == simulate(compose(Scenario)) with the
    registry dominance — the Scenario overload adds no PRNG consumption."""
    sc = make_scenario("zhong_density")
    eng = EngineConfig(engine="batched")
    run = RunConfig(length=16, height=16, mcs=3, chunk_mcs=3, seed=1)
    r_sc = simulate(sc, engine_config=eng, run_config=run,
                    stop_on_stasis=False)
    r_flat = simulate(compose(sc, eng, run), sc.dominance(),
                      stop_on_stasis=False)
    np.testing.assert_array_equal(r_sc.grid, r_flat.grid)
    np.testing.assert_array_equal(r_sc.densities, r_flat.densities)


def test_trial_driver_accepts_scenarios():
    sc = make_scenario("nspecies5")
    run = RunConfig(length=16, height=16, seed=2)
    r_sc = run_trials(sc, None, 2, n_mcs=2, stop_on_stasis=False,
                      run_config=run)
    r_flat = run_trials(compose(sc, None, run), sc.dominance(), 2,
                        n_mcs=2, stop_on_stasis=False)
    np.testing.assert_array_equal(r_sc.survival, r_flat.survival)
    np.testing.assert_array_equal(r_sc.densities, r_flat.densities)


# ----------------------------- CLI acceptance ------------------------------ #

@pytest.mark.parametrize("engine", ("batched", "sublattice", "sharded_pod"))
@pytest.mark.parametrize("scenario", PRESETS)
def test_every_scenario_runs_through_cli_on_every_engine_tier(scenario,
                                                              engine):
    """Acceptance criterion: every registered scenario runs through the
    CLI ``--scenario`` resolution path on the vmapped, tiled and
    composed-mesh engines."""
    ap = build_parser()
    args = ap.parse_args(["--scenario", scenario, "--engine", engine,
                          "--length", "16", "--height", "16",
                          "--mcs", "2", "--chunkMcs", "2",
                          "--tile", "8", "16"])
    sc, params, dom = scenario_setup(args, ap)
    assert params.engine == engine and params.species == sc.species
    res = simulate(params, dom, stop_on_stasis=False)
    assert res.mcs_completed == 2
    np.testing.assert_allclose(res.densities.sum(axis=1), 1.0, atol=1e-6)


def test_cli_explicit_flags_override_the_preset():
    ap = build_parser()
    args = ap.parse_args(["--scenario", "zhong_density",
                          "--mobility", "5e-4", "--empty", "0.2"])
    sc = sc_mod.scenario_from_cli(args, ap)
    assert sc.mobility == 5e-4 and sc.empty == 0.2
    assert sc.species == 5          # un-passed physics stay preset-owned


# ------------------------ nspecies relabel symmetry ------------------------ #

def test_nspecies_relabeling_symmetry():
    """The cyclic family is equivariant under cyclic species relabeling:
    rotating every label in the initial lattice rotates the whole
    trajectory (the circulant dominance network is rotation-invariant and
    the engines consume cell values only through dominance lookups)."""
    sc = make_scenario("nspecies5")
    p = compose(sc, EngineConfig(engine="batched"),
                RunConfig(length=12, height=12, mcs=3, chunk_mcs=3, seed=6))
    dom = sc.dominance()
    key = jax.random.PRNGKey(123)
    grid0 = np.asarray(lattice.init_grid(
        jax.random.fold_in(key, 1), p.height, p.length, p.species, 0.1))
    lut = np.array([0] + [i % sc.species + 1
                          for i in range(1, sc.species + 1)])
    r = simulate(p, dom, grid0=grid0, key=key, stop_on_stasis=False)
    r_rot = simulate(p, dom, grid0=lut[grid0], key=key,
                     stop_on_stasis=False)
    np.testing.assert_array_equal(r_rot.grid, lut[r.grid])


# ----------------------- ENGINES back-compat alias ------------------------- #

def test_engines_alias_tracks_late_registration():
    """params.ENGINES / repro.core.ENGINES are live views of the engine
    registry (module __getattr__), not an import-time snapshot — a
    late-registered engine must appear in both."""
    import repro.core as core
    from repro.core import params as params_mod
    name = "dummy_late_engine"
    assert name not in params_mod.ENGINES

    @engines.register(name, engines.EngineCaps(
        description="late-registration probe"))
    def _build_dummy(p, d):            # pragma: no cover - never built
        raise NotImplementedError
    try:
        assert name in params_mod.ENGINES
        assert name in core.ENGINES
        assert tuple(params_mod.ENGINES) == engines.engine_names()
    finally:
        engines._REGISTRY.pop(name, None)
    assert name not in params_mod.ENGINES


# --------------------- scenario content hash (§12) ------------------------- #

class TestScenarioKey:
    """``scenarios.scenario_key`` — the serving cache's physics hash
    (DESIGN.md §12): deterministic per content, insensitive to field
    construction order, sensitive to every physics field."""

    def test_every_preset_hashes_stably_twice(self):
        for name in scenario_names():
            a = sc_mod.scenario_key(make_scenario(name))
            b = sc_mod.scenario_key(make_scenario(name))
            assert a == b, name
            assert len(a) == 16 and int(a, 16) >= 0, a

    def test_every_parametric_preset_hashes_stably_twice(self):
        for name in PRESETS:
            assert sc_mod.scenario_key(make_scenario(name)) == \
                sc_mod.scenario_key(make_scenario(name)), name

    def test_distinct_scenarios_distinct_keys(self):
        keys = {sc_mod.scenario_key(make_scenario(n)) for n in PRESETS}
        assert len(keys) == len(PRESETS)

    def test_extras_iteration_order_does_not_move_the_key(self):
        """The historical hazard: dict/tuple extras in different insertion
        orders must hash identically — ``__post_init__`` canonicalizes."""
        a = Scenario(name="adhoc", species=3,
                     extras={"mobility": 3e-4, "epsilon": 0.4})
        b = Scenario(name="adhoc", species=3,
                     extras={"epsilon": 0.4, "mobility": 3e-4})
        assert a == b
        assert sc_mod.scenario_key(a) == sc_mod.scenario_key(b)
        c = Scenario(name="adhoc", species=3,
                     extras=(("epsilon", 0.4), ("mobility", 3e-4)))
        assert sc_mod.scenario_key(c) == sc_mod.scenario_key(a)

    def test_key_moves_with_physics(self):
        base = make_scenario("park3")
        k0 = sc_mod.scenario_key(base)
        assert sc_mod.scenario_key(base.replace(empty=0.5)) != k0
        assert sc_mod.scenario_key(
            base.replace(extras={"mobility": 1e-3})) != k0

    def test_key_is_cross_process_stable(self, subproc):
        """Not ``hash()``-based: the same scenario must hash identically
        in a fresh interpreter (PYTHONHASHSEED varies)."""
        here = {n: sc_mod.scenario_key(make_scenario(n)) for n in PRESETS}
        out = subproc("""
            import json
            from repro.core import scenarios as sc
            names = %r
            print(json.dumps({n: sc.scenario_key(sc.make_scenario(n))
                              for n in names}))
        """ % (list(PRESETS),), 1)
        there = json.loads(out.strip().splitlines()[-1])
        assert there == here
