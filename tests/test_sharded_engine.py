"""The registry-driven sharded halo-exchange engine.

Single-device tests run on the real CPU device (a 1x1 lattice mesh);
multi-device tests spawn subprocesses with fake CPU devices (see conftest).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # hermetic container: deterministic fallback sampler
    from _propcheck import given, settings, strategies as st

from repro.core import EscgParams, dominance as dm, engines, simulate
from repro.core.lattice import init_grid


# --------------------- N=1 shard == sublattice engine --------------------- #

@given(seed=st.integers(0, 10_000), species=st.integers(2, 6),
       cfg=st.sampled_from([(16, 32, 8, 16), (24, 24, 8, 8),
                            (16, 16, 4, 8)]),
       nbhd=st.sampled_from([4, 8]))
@settings(max_examples=10, deadline=None)
def test_sharded_single_shard_bit_identical_to_sublattice(seed, species,
                                                          cfg, nbhd):
    """A sharded run with one shard is bit-identical to the sublattice
    engine: same per-tile Philox streams, same shifted-window sweeps."""
    h, w, th, tw = cfg
    kw = dict(length=w, height=h, species=species, neighbourhood=nbhd,
              tile=(th, tw), seed=seed, mobility=1e-3, empty=0.1)
    dom = dm.circulant(species, (1, 2) if species >= 5 else (1,))
    dom_j = jnp.asarray(dom, jnp.float32)

    sub = engines.build(EscgParams(engine="sublattice", **kw), dom_j)
    shd = engines.build(EscgParams(engine="sharded", shard_grid=(1, 1),
                                   **kw), dom_j)
    key = jax.random.PRNGKey(seed)
    key, k0 = jax.random.split(key)
    g_sub = init_grid(k0, h, w, species, 0.1)
    g_shd = jax.device_put(g_sub, shd.grid_sharding)
    for _ in range(3):
        key, k = jax.random.split(key)
        g_sub, kept_a, att_a = sub.one_mcs(g_sub, k)
        g_shd, kept_b, att_b = shd.one_mcs(g_shd, k)
        assert int(att_a) == int(att_b)
    assert jnp.array_equal(g_sub, g_shd)


def test_sharded_through_simulate_single_device():
    """Full driver path: engine='sharded' on one device tracks
    engine='sublattice' exactly (grids, densities, stasis accounting)."""
    kw = dict(length=32, height=16, species=3, mcs=6, chunk_mcs=3,
              tile=(8, 8), seed=0, mobility=1e-3, empty=0.1)
    r1 = simulate(EscgParams(engine="sublattice", **kw),
                  stop_on_stasis=False)
    r2 = simulate(EscgParams(engine="sharded", **kw), stop_on_stasis=False)
    np.testing.assert_array_equal(r1.grid, r2.grid)
    np.testing.assert_allclose(r1.densities, r2.densities, atol=0)
    assert r1.mcs_completed == r2.mcs_completed


def test_sharded_rejects_infeasible_grid():
    p = EscgParams(length=32, height=16, engine="sharded", tile=(8, 8),
                   shard_grid=(3, 1))   # 3 does not divide 16
    with pytest.raises(ValueError):
        engines.build(p, jnp.asarray(dm.RPS()))


def test_run_trials_rejects_sharded():
    from repro.core import run_trials
    with pytest.raises(ValueError, match="vmappable"):
        run_trials(EscgParams(length=16, height=16, engine="sharded",
                              tile=(8, 8)), dm.RPS(), n_trials=2, n_mcs=1)


# ----------------------------- multi-device ------------------------------- #

@pytest.mark.slow
def test_sharded_shard_count_invariance(subproc):
    """Conserved cell counts and identical survivor statistics across shard
    layouts on 4 fake devices — the trajectory is a function of (key, tile
    id) only, so every decomposition is bit-identical."""
    out = subproc("""
        import jax, numpy as np
        from repro.core import EscgParams, dominance as dm, simulate
        kw = dict(length=64, height=32, species=5, mcs=4, chunk_mcs=2,
                  tile=(8, 16), seed=3, mobility=1e-3, empty=0.1)
        base = simulate(EscgParams(engine="sublattice", **kw),
                        dm.RPSLS(), stop_on_stasis=False)
        n0 = base.densities[0].sum()
        for sg in ((1, 1), (2, 2), (4, 1), (1, 4), (2, 1)):
            r = simulate(EscgParams(engine="sharded", shard_grid=sg, **kw),
                         dm.RPSLS(), stop_on_stasis=False)
            assert np.array_equal(r.grid, base.grid), sg
            assert np.array_equal(r.densities, base.densities), sg
            # conservation: every MCS's counts sum to N
            assert np.allclose(r.densities.sum(axis=1), n0), sg
            surv = r.densities[-1][1:] > 0
            assert np.array_equal(surv, base.densities[-1][1:] > 0), sg
        print("SHARD_INVARIANT")
    """, n_devices=4)
    assert "SHARD_INVARIANT" in out


@pytest.mark.slow
def test_sharded_256_grid_across_4_devices(subproc):
    """Acceptance: a 256x256 grid runs device-resident across 4 fake CPU
    devices with counts matching a single-device run."""
    out = subproc("""
        import numpy as np
        from repro.core import EscgParams, simulate
        kw = dict(length=256, height=256, species=3, mcs=2, chunk_mcs=2,
                  tile=(8, 16), seed=0, mobility=1e-4, empty=0.1)
        multi = simulate(EscgParams(engine="sharded", shard_grid=(2, 2),
                                    **kw), stop_on_stasis=False)
        single = simulate(EscgParams(engine="sharded", shard_grid=(1, 1),
                                     **kw), stop_on_stasis=False)
        assert np.array_equal(multi.grid, single.grid)
        assert np.array_equal(multi.densities, single.densities)
        assert int(multi.densities[-1].sum() * 256 * 256) == 256 * 256
        print("OK_256", np.round(multi.densities[-1], 4))
    """, n_devices=4)
    assert "OK_256" in out


@pytest.mark.slow
def test_halo_roll_matches_global_roll(subproc):
    """The ppermute halo exchange equals a global torus roll, under jit,
    for every shift — including the jax-0.4.x pattern (roll of a shard_map
    output) that miscompiles and motivated the in-region design."""
    out = subproc("""
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.core.sharded import shard_shift2d
        from repro.parallel.sharding import lattice_mesh

        mesh = lattice_mesh((2, 2), 32, 64, 8, 16)
        x = jnp.arange(32 * 64, dtype=jnp.int32).reshape(32, 64)

        @partial(jax.jit, static_argnums=2)
        def roll(x, s, reverse):
            f = partial(shard_shift2d, tile_shape=(8, 16), shard_grid=(2, 2),
                        reverse=reverse)
            return shard_map(f, mesh=mesh, in_specs=(P("rows", "cols"), P()),
                             out_specs=P("rows", "cols"),
                             check_rep=False)(x, s)

        for sy in (0, 3, 7):
            for sx in (0, 5, 15):
                s = jnp.array([sy, sx], jnp.int32)
                want = np.roll(np.asarray(x), (-sy, -sx), (0, 1))
                got = np.asarray(roll(x, s, False))
                assert np.array_equal(got, want), (sy, sx)
                back = np.asarray(roll(jnp.asarray(got), s, True))
                assert np.array_equal(back, np.asarray(x)), (sy, sx, "rev")
        print("HALO_OK")
    """, n_devices=4)
    assert "HALO_OK" in out
