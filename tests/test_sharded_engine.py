"""The sharded (grid-axis) and sharded_pod (composed) engines — ONE
module, parametrized over the in-region tile-sweep implementation
(``local_kernel``: jnp vs pallas; the two paths are bit-identical by
contract). Merges the former tests/test_sharded.py ESCG tests.

Single-device tests run on the real CPU device (a 1x1 lattice mesh);
multi-device tests spawn subprocesses with fake CPU devices (see
conftest). LM-scaffold multi-device tests live in
tests/test_parallel_scaffold.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # hermetic container: deterministic fallback sampler
    from _propcheck import given, settings, strategies as st

from repro.core import EscgParams, dominance as dm, engines, simulate
from repro.core.lattice import init_grid

pytestmark = pytest.mark.composed   # re-run by the CI 8-fake-device job

LOCAL_KERNELS = ("jnp", "pallas")


# --------------------- N=1 shard == sublattice engine --------------------- #

@given(seed=st.integers(0, 10_000), species=st.integers(2, 6),
       cfg=st.sampled_from([(16, 32, 8, 16), (24, 24, 8, 8),
                            (16, 16, 4, 8)]),
       nbhd=st.sampled_from([4, 8]),
       local_kernel=st.sampled_from(LOCAL_KERNELS))
@settings(max_examples=10, deadline=None)
def test_sharded_single_shard_bit_identical_to_sublattice(seed, species,
                                                          cfg, nbhd,
                                                          local_kernel):
    """A sharded run with one shard is bit-identical to the sublattice
    engine — for BOTH tile-sweep implementations: same per-tile Philox
    streams, same shifted-window sweeps."""
    h, w, th, tw = cfg
    kw = dict(length=w, height=h, species=species, neighbourhood=nbhd,
              tile=(th, tw), seed=seed, mobility=1e-3, empty=0.1)
    dom = dm.circulant(species, (1, 2) if species >= 5 else (1,))
    dom_j = jnp.asarray(dom, jnp.float32)

    sub = engines.build(EscgParams(engine="sublattice", **kw), dom_j)
    shd = engines.build(EscgParams(engine="sharded", shard_grid=(1, 1),
                                   local_kernel=local_kernel, **kw), dom_j)
    key = jax.random.PRNGKey(seed)
    key, k0 = jax.random.split(key)
    g_sub = init_grid(k0, h, w, species, 0.1)
    g_shd = jax.device_put(g_sub, shd.grid_sharding)
    for _ in range(3):
        key, k = jax.random.split(key)
        g_sub, kept_a, att_a = sub.one_mcs(g_sub, k)
        g_shd, kept_b, att_b = shd.one_mcs(g_shd, k)
        assert int(att_a) == int(att_b)
    assert jnp.array_equal(g_sub, g_shd)


@pytest.mark.parametrize("local_kernel", LOCAL_KERNELS)
def test_sharded_through_simulate_single_device(local_kernel):
    """Full driver path: engine='sharded' on one device tracks
    engine='sublattice' exactly (grids, densities, stasis accounting)."""
    kw = dict(length=32, height=16, species=3, mcs=6, chunk_mcs=3,
              tile=(8, 8), seed=0, mobility=1e-3, empty=0.1)
    r1 = simulate(EscgParams(engine="sublattice", **kw),
                  stop_on_stasis=False)
    r2 = simulate(EscgParams(engine="sharded", local_kernel=local_kernel,
                             **kw), stop_on_stasis=False)
    np.testing.assert_array_equal(r1.grid, r2.grid)
    np.testing.assert_allclose(r1.densities, r2.densities, atol=0)
    assert r1.mcs_completed == r2.mcs_completed


@pytest.mark.parametrize("local_kernel", LOCAL_KERNELS)
def test_sharded_pod_through_trials_single_device(local_kernel):
    """Composed-engine driver path on one device: run_trials with a
    (1,1,1) mesh tracks the vmapped sublattice trial batch exactly."""
    from repro.core.trials import run_trials
    kw = dict(length=16, height=16, species=5, mobility=1e-3, tile=(8, 8),
              empty=0.1, seed=4)
    dom = dm.RPSLS()
    base = run_trials(EscgParams(engine="sublattice", **kw), dom, 3,
                      n_mcs=4, stop_on_stasis=False)
    r = run_trials(EscgParams(engine="sharded_pod", mesh_shape=(1, 1, 1),
                              local_kernel=local_kernel, **kw), dom, 3,
                   n_mcs=4, stop_on_stasis=False)
    np.testing.assert_array_equal(r.survival, base.survival)
    np.testing.assert_array_equal(r.densities, base.densities)
    np.testing.assert_array_equal(r.stasis_mcs, base.stasis_mcs)
    np.testing.assert_array_equal(r.extinction_mcs, base.extinction_mcs)


# ------------------------- capability validation --------------------------- #

def test_sharded_rejects_infeasible_grid():
    p = EscgParams(length=32, height=16, engine="sharded", tile=(8, 8),
                   shard_grid=(3, 1))   # 3 does not divide 16
    with pytest.raises(ValueError):
        engines.build(p, jnp.asarray(dm.RPS()))


def test_run_trials_rejects_sharded():
    from repro.core import run_trials
    with pytest.raises(ValueError, match="vmappable"):
        run_trials(EscgParams(length=16, height=16, engine="sharded",
                              tile=(8, 8)), dm.RPS(), n_trials=2, n_mcs=1)


def test_mesh_shape_legality_is_registry_driven():
    """EngineCaps.mesh_axes (not the drivers) decide which layouts are
    legal: mesh_shape on a non-composable engine, wrong rank, and bad dims
    all fail at params validation."""
    with pytest.raises(ValueError, match="pod-composable"):
        EscgParams(engine="sublattice", tile=(8, 8), length=16, height=16,
                   mesh_shape=(1, 1, 1)).validate()
    with pytest.raises(ValueError, match="pod-composable"):
        EscgParams(engine="sharded", tile=(8, 8), length=16, height=16,
                   mesh_shape=(1, 1, 1)).validate()
    with pytest.raises(ValueError, match="dims must be >= 1"):
        EscgParams(engine="sharded_pod", tile=(8, 8), length=16, height=16,
                   mesh_shape=(0, 1, 1)).validate()
    # legal on the composed engine
    EscgParams(engine="sharded_pod", tile=(8, 8), length=16, height=16,
               mesh_shape=(1, 1, 1)).validate()


def test_local_kernel_validation():
    with pytest.raises(ValueError, match="local_kernel"):
        EscgParams(engine="sharded", tile=(8, 8), length=16, height=16,
                   local_kernel="cuda").validate()
    # engines that declare supported kernels accept exactly those
    for lk in ("pallas", "fused"):
        EscgParams(engine="sharded", tile=(8, 8), length=16, height=16,
                   local_kernel=lk).validate()
    # engines that don't consume the knob ignore it (same rule as tile)
    EscgParams(engine="batched", local_kernel="pallas").validate()


# --------------- fused local kernel: the second oracle family -------------- #
# jnp/pallas local kernels answer to `sublattice` (the tests above); the
# fused kernel derives proposals in-kernel from Philox counters and answers
# to `pallas_fused` instead (EngineCaps.equiv_oracles, DESIGN.md §6).

def test_sharded_fused_tracks_pallas_fused():
    """engine='sharded', local_kernel='fused' on a 1x1 mesh follows the
    single-device pallas_fused engine bit-for-bit through simulate."""
    kw = dict(length=32, height=16, species=3, mcs=6, chunk_mcs=3,
              tile=(8, 8), seed=0, mobility=1e-3, empty=0.1)
    r1 = simulate(EscgParams(engine="pallas_fused", **kw),
                  stop_on_stasis=False)
    r2 = simulate(EscgParams(engine="sharded", local_kernel="fused", **kw),
                  stop_on_stasis=False)
    np.testing.assert_array_equal(r1.grid, r2.grid)
    np.testing.assert_allclose(r1.densities, r2.densities, atol=0)
    assert r1.mcs_completed == r2.mcs_completed


def test_sharded_pod_fused_through_trials():
    """Composed-engine driver path: run_trials with a (1,1,1) mesh and
    local_kernel='fused' tracks the vmapped pallas_fused batch exactly."""
    from repro.core.trials import run_trials
    kw = dict(length=16, height=16, species=5, mobility=1e-3, tile=(8, 8),
              empty=0.1, seed=4)
    dom = dm.RPSLS()
    base = run_trials(EscgParams(engine="pallas_fused", **kw), dom, 3,
                      n_mcs=4, stop_on_stasis=False)
    r = run_trials(EscgParams(engine="sharded_pod", mesh_shape=(1, 1, 1),
                              local_kernel="fused", **kw), dom, 3,
                   n_mcs=4, stop_on_stasis=False)
    np.testing.assert_array_equal(r.survival, base.survival)
    np.testing.assert_array_equal(r.densities, base.densities)
    np.testing.assert_array_equal(r.stasis_mcs, base.stasis_mcs)
    np.testing.assert_array_equal(r.extinction_mcs, base.extinction_mcs)


def test_sharded_pod_rejects_trial_devices():
    from repro.core.trials import run_trials
    with pytest.raises(ValueError, match="mesh_shape"):
        run_trials(EscgParams(engine="sharded_pod", tile=(8, 8), length=16,
                              height=16), dm.RPS(), n_trials=2, n_mcs=1,
                   trial_devices=2)


def test_mesh_shape_needs_enough_devices():
    p = EscgParams(engine="sharded_pod", tile=(8, 8), length=16, height=16,
                   mesh_shape=(64, 1, 1))
    with pytest.raises(ValueError, match="devices"):
        engines.build(p, jnp.asarray(dm.RPS()))


def test_make_composed_mesh_axes():
    """launch.mesh builds the same ('pod','rows','cols') layout the
    sharded_pod engine uses, with or without lattice validation."""
    from repro.launch.mesh import make_composed_mesh
    m = make_composed_mesh((1, 1, 1))
    assert m.axis_names == ("pod", "rows", "cols")
    m2 = make_composed_mesh((1, 1, 1), height=16, width=16, tile=(8, 8))
    assert (m2.shape["pod"], m2.shape["rows"], m2.shape["cols"]) == (1, 1, 1)
    # rejected either for the device budget (1 device) or, with enough
    # devices, because cols=2 cannot split width 16 into 16-wide tiles
    with pytest.raises(ValueError):
        make_composed_mesh((1, 1, 2), height=16, width=16, tile=(8, 16))


def test_mesh_shape_cli_parser():
    from repro.core.params import _mesh_shape
    assert _mesh_shape("2,2,2") == (2, 2, 2)
    assert _mesh_shape("4x1x2") == (4, 1, 2)
    import argparse
    with pytest.raises(argparse.ArgumentTypeError):
        _mesh_shape("2,2")


# ----------------------------- multi-device ------------------------------- #

@pytest.mark.slow
@pytest.mark.parametrize("local_kernel", LOCAL_KERNELS)
def test_sharded_escg_equals_single_device(subproc, local_kernel):
    """The shard_map spatial decomposition is bit-identical to the
    single-device sublattice engine on a 4x4 device mesh, with externally
    supplied proposals, for both tile-sweep implementations."""
    out = subproc(f"""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import dominance as dm
        from repro.core.lattice import init_grid
        from repro.core.rng import tile_proposal_batch, round_shift
        from repro.core.sharded import sharded_run_round
        from repro.core.sublattice import run_round
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((4, 4), ("data", "model"))
        h, w, th, tw = 32, 64, 8, 16
        key = jax.random.PRNGKey(0)
        grid = init_grid(key, h, w, 5, 0.1)
        dom = jnp.asarray(dm.RPSLS())
        nt = (h // th) * (w // tw)
        for r in range(3):
            kp, ks, key = jax.random.split(key, 3)
            props = tile_proposal_batch(kp, nt, 61, (th-2)*(tw-2), 4)
            shift = round_shift(ks, th, tw)
            a = run_round(grid, props, shift, (th, tw), 0.3, 0.6, dom)
            b = sharded_run_round(grid, props, shift, (th, tw), 0.3, 0.6,
                                  dom, mesh,
                                  local_kernel={local_kernel!r})
            assert jnp.array_equal(a, b), f"round {{r}} diverged"
            grid = a
        print("EXACT_MATCH")
    """, n_devices=16)
    assert "EXACT_MATCH" in out


@pytest.mark.slow
def test_sharded_simulation_runs(subproc):
    out = subproc("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import dominance as dm, metrics
        from repro.core.lattice import init_grid
        from repro.core.params import EscgParams
        from repro.core.sharded import make_sharded_simulation
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((2, 4), ("data", "model"))
        p = EscgParams(length=64, height=32, species=3, mobility=1e-4,
                       engine="sublattice", tile=(8, 16), seed=0)
        grid_sh, one_mcs = make_sharded_simulation(p, dm.RPS(), mesh)
        key = jax.random.PRNGKey(0)
        grid = jax.device_put(init_grid(key, 32, 64, 3, 0.1), grid_sh)
        for i in range(5):
            key, k = jax.random.split(key)
            grid = one_mcs(grid, k)
        c = metrics.counts(grid, 3)
        assert int(c.sum()) == 32 * 64
        print("OK", np.asarray(c))
    """, n_devices=8)
    assert "OK" in out


@pytest.mark.slow
def test_sharded_shard_count_invariance(subproc):
    """Conserved cell counts and identical survivor statistics across shard
    layouts on 4 fake devices — the trajectory is a function of (key, tile
    id) only, so every decomposition is bit-identical."""
    out = subproc("""
        import jax, numpy as np
        from repro.core import EscgParams, dominance as dm, simulate
        kw = dict(length=64, height=32, species=5, mcs=4, chunk_mcs=2,
                  tile=(8, 16), seed=3, mobility=1e-3, empty=0.1)
        base = simulate(EscgParams(engine="sublattice", **kw),
                        dm.RPSLS(), stop_on_stasis=False)
        n0 = base.densities[0].sum()
        for sg in ((1, 1), (2, 2), (4, 1), (1, 4), (2, 1)):
            r = simulate(EscgParams(engine="sharded", shard_grid=sg, **kw),
                         dm.RPSLS(), stop_on_stasis=False)
            assert np.array_equal(r.grid, base.grid), sg
            assert np.array_equal(r.densities, base.densities), sg
            # conservation: every MCS's counts sum to N
            assert np.allclose(r.densities.sum(axis=1), n0), sg
            surv = r.densities[-1][1:] > 0
            assert np.array_equal(surv, base.densities[-1][1:] > 0), sg
        print("SHARD_INVARIANT")
    """, n_devices=4)
    assert "SHARD_INVARIANT" in out


@pytest.mark.slow
def test_sharded_256_grid_across_4_devices(subproc):
    """Acceptance: a 256x256 grid runs device-resident across 4 fake CPU
    devices with counts matching a single-device run."""
    out = subproc("""
        import numpy as np
        from repro.core import EscgParams, simulate
        kw = dict(length=256, height=256, species=3, mcs=2, chunk_mcs=2,
                  tile=(8, 16), seed=0, mobility=1e-4, empty=0.1)
        multi = simulate(EscgParams(engine="sharded", shard_grid=(2, 2),
                                    **kw), stop_on_stasis=False)
        single = simulate(EscgParams(engine="sharded", shard_grid=(1, 1),
                                     **kw), stop_on_stasis=False)
        assert np.array_equal(multi.grid, single.grid)
        assert np.array_equal(multi.densities, single.densities)
        assert int(multi.densities[-1].sum() * 256 * 256) == 256 * 256
        print("OK_256", np.round(multi.densities[-1], 4))
    """, n_devices=4)
    assert "OK_256" in out


@pytest.mark.slow
def test_halo_roll_matches_global_roll(subproc):
    """The ppermute halo exchange equals a global torus roll, under jit,
    for every shift — including the jax-0.4.x pattern (roll of a shard_map
    output) that miscompiles and motivated the in-region design."""
    out = subproc("""
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.core.sharded import shard_shift2d
        from repro.parallel.sharding import lattice_mesh

        mesh = lattice_mesh((2, 2), 32, 64, 8, 16)
        x = jnp.arange(32 * 64, dtype=jnp.int32).reshape(32, 64)

        @partial(jax.jit, static_argnums=2)
        def roll(x, s, reverse):
            f = partial(shard_shift2d, tile_shape=(8, 16), shard_grid=(2, 2),
                        reverse=reverse)
            return shard_map(f, mesh=mesh, in_specs=(P("rows", "cols"), P()),
                             out_specs=P("rows", "cols"),
                             check_rep=False)(x, s)

        for sy in (0, 3, 7):
            for sx in (0, 5, 15):
                s = jnp.array([sy, sx], jnp.int32)
                want = np.roll(np.asarray(x), (-sy, -sx), (0, 1))
                got = np.asarray(roll(x, s, False))
                assert np.array_equal(got, want), (sy, sx)
                back = np.asarray(roll(jnp.asarray(got), s, True))
                assert np.array_equal(back, np.asarray(x)), (sy, sx, "rev")
        print("HALO_OK")
    """, n_devices=4)
    assert "HALO_OK" in out


@pytest.mark.slow
def test_vmapped_trials_over_pod_axis(subproc):
    """IID ESCG trials sharded over a 'pod' axis (the multi-pod statistics
    story, DESIGN.md §5)."""
    out = subproc("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core import dominance as dm
        from repro.core.lattice import init_grid
        from repro.core.params import EscgParams
        from repro.core.simulation import build_mcs_fn
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((4, 2), ("pod", "data"))
        p = EscgParams(length=16, height=16, species=3, mobility=1e-4,
                       engine="batched", seed=0)
        one = build_mcs_fn(p, jnp.asarray(dm.RPS()))
        def trial(grid, key):
            for i in range(3):
                key, k = jax.random.split(key)
                grid, _, _ = one(grid, k)
            return grid
        keys = jax.random.split(jax.random.PRNGKey(0), 8)
        grids = jax.vmap(lambda k: init_grid(k, 16, 16, 3, 0.1))(keys)
        grids = jax.device_put(grids,
                               NamedSharding(mesh, P("pod", "data", None)))
        out = jax.jit(jax.vmap(trial))(grids, keys)
        assert out.shape == (8, 16, 16)
        print("PODS_OK")
    """, n_devices=8)
    assert "PODS_OK" in out


@pytest.mark.slow
def test_composed_mesh_cli_path(subproc):
    """--trials + --engine sharded_pod --meshShape drives the composed
    mesh end-to-end through the CLI entry point."""
    out = subproc("""
        import sys
        sys.argv = ["escg_run", "--length", "32", "--height", "32",
                    "--species", "5", "--mcs", "4", "--chunkMcs", "2",
                    "--tile", "8", "8", "--trials", "4",
                    "--engine", "sharded_pod", "--meshShape", "2,2,2",
                    "--mobility", "0.001"]
        from repro.launch.escg_run import main
        main()
    """, n_devices=8)
    assert "survival probabilities" in out
