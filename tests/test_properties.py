"""Property-based invariants of the composed mesh machinery (DESIGN.md §6).

Two layers, matching the repo's device-count test policy (conftest):

* fast host-level properties (hypothesis, or the deterministic
  ``_propcheck`` fallback) exercise the single-shard paths;
* ``slow`` subprocess properties run the REAL multi-device paths on fake
  CPU devices, drawing their examples from a seeded ``random.Random`` so
  every CI run replays the same cases — the acceptance property is that
  ``run_trials`` on ANY random (P, R, C) factorization of 8 devices is
  bit-identical to the (1, 1, 1) layout (and hence, via
  tests/test_engine_equivalence.py, to the single-device ``sublattice``
  engine).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # hermetic container: deterministic fallback sampler
    from _propcheck import given, settings, strategies as st

from repro.core.sharded import halo_roll

pytestmark = pytest.mark.composed   # re-run by the CI 8-fake-device job


# ------------------------- fast host-level layer -------------------------- #

@given(extent=st.sampled_from([8, 16, 24]), s=st.integers(0, 7),
       axis=st.sampled_from([0, 1]), reverse=st.booleans())
@settings(max_examples=20, deadline=None)
def test_halo_roll_single_shard_is_torus_roll(extent, s, axis, reverse):
    """n_shards=1 collapses halo_roll to a plain torus roll, and
    forward-then-reverse is the identity for every shift."""
    x = jnp.arange(extent * extent, dtype=jnp.int32).reshape(extent, extent)
    sh = jnp.int32(s)
    fwd = halo_roll(x, sh, halo=8, axis_name="rows", axis=axis, n_shards=1)
    want = np.roll(np.asarray(x), s if reverse else -s, axis)
    got = (halo_roll(x, sh, 8, "rows", axis, 1, reverse=True)
           if reverse else fwd)
    assert np.array_equal(np.asarray(got), want)
    back = halo_roll(fwd, sh, 8, "rows", axis, 1, reverse=True)
    assert np.array_equal(np.asarray(back), np.asarray(x))


@given(p=st.integers(1, 4), r=st.integers(1, 2), c=st.integers(1, 2),
       n=st.integers(1, 17))
@settings(max_examples=25, deadline=None)
def test_padding_is_pod_width_only(p, r, c, n):
    """The composed batch pads to a multiple of the pod width P alone —
    grid-axis factors shard H/W, never the trial axis."""
    from repro.core.trials import pad_trials
    n_pad = pad_trials(n, p)
    assert n_pad >= n and n_pad % p == 0 and n_pad - n < p


# --------------------------- multi-device layer --------------------------- #

@pytest.mark.slow
def test_halo_roll_round_trip_random_shifts(subproc):
    """Property: on a (2, 2) device mesh, shard_shift2d for ANY random
    shift equals the global torus roll, and forward-then-reverse is the
    identity (seeded sampling over the full [0,th) x [0,tw) range)."""
    out = subproc("""
        import random
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.core.sharded import shard_shift2d
        from repro.parallel.sharding import lattice_mesh

        th, tw = 8, 16
        mesh = lattice_mesh((2, 2), 32, 64, th, tw)
        x = jnp.arange(32 * 64, dtype=jnp.int32).reshape(32, 64)

        @partial(jax.jit, static_argnums=2)
        def roll(x, s, reverse):
            f = partial(shard_shift2d, tile_shape=(th, tw),
                        shard_grid=(2, 2), reverse=reverse)
            return shard_map(f, mesh=mesh,
                             in_specs=(P("rows", "cols"), P()),
                             out_specs=P("rows", "cols"),
                             check_rep=False)(x, s)

        rng = random.Random("halo_roll_round_trip")
        for i in range(12):
            sy, sx = rng.randrange(th), rng.randrange(tw)
            s = jnp.array([sy, sx], jnp.int32)
            got = np.asarray(roll(x, s, False))
            want = np.roll(np.asarray(x), (-sy, -sx), (0, 1))
            assert np.array_equal(got, want), ("fwd", i, sy, sx)
            back = np.asarray(roll(jnp.asarray(got), s, True))
            assert np.array_equal(back, np.asarray(x)), ("rev", i, sy, sx)
        print("HALO_PROPERTY_OK")
    """, n_devices=4)
    assert "HALO_PROPERTY_OK" in out


@pytest.mark.slow
def test_mesh_factorization_invariance(subproc):
    """Acceptance property: run_trials over a composed ('pod','rows',
    'cols') mesh is bit-identical to the (1,1,1) layout for random legal
    factorizations of 8 fake devices — trial keys and tile streams are
    functions of global identity only, never of the layout."""
    out = subproc("""
        import random
        import numpy as np
        from repro.core import EscgParams, dominance as dm
        from repro.core.trials import run_trials

        kw = dict(length=32, height=32, species=5, mobility=1e-3,
                  tile=(8, 8), empty=0.1, seed=13, engine='sharded_pod')
        dom = dm.RPSLS()

        def run(ms):
            return run_trials(EscgParams(mesh_shape=ms, **kw), dom,
                              n_trials=5, n_mcs=4, chunk_mcs=2,
                              stop_on_stasis=False)

        base = run((1, 1, 1))
        # every (P, R, C) with P*R*C == 8 that the 32x32/tile(8,8)
        # lattice admits (rows, cols must split it into tile multiples)
        legal = [(p, r, c)
                 for p in (1, 2, 4, 8) for r in (1, 2, 4) for c in (1, 2, 4)
                 if p * r * c == 8]
        assert len(legal) >= 6, legal
        rng = random.Random("mesh_factorization")
        for ms in rng.sample(legal, 5):
            r = run(ms)
            assert r.n_devices == 8, ms
            assert np.array_equal(r.survival, base.survival), ms
            assert np.array_equal(r.densities, base.densities), ms
            assert np.array_equal(r.stasis_mcs, base.stasis_mcs), ms
            assert np.array_equal(r.extinction_mcs,
                                  base.extinction_mcs), ms
        print("FACTORIZATION_INVARIANT")
    """, n_devices=8)
    assert "FACTORIZATION_INVARIANT" in out


@pytest.mark.slow
def test_fused_local_kernel_factorization_invariance(subproc):
    """Acceptance property for the fused-Philox family: run_trials with
    ``engine='sharded_pod', local_kernel='fused'`` on ANY random legal
    (P, R, C) factorization of 8 fake devices is bit-identical to the
    (1, 1, 1) layout AND to the single-device ``pallas_fused`` engine's
    pod-sharded trial batch — in-kernel counters are keyed by global
    (trial, tile) identity only, never by the mesh layout."""
    out = subproc("""
        import random
        import numpy as np
        from repro.core import EscgParams, dominance as dm
        from repro.core.trials import run_trials

        kw = dict(length=32, height=32, species=5, mobility=1e-3,
                  tile=(8, 8), empty=0.1, seed=17)
        dom = dm.RPSLS()

        def run(engine, ms=None, lk='jnp'):
            return run_trials(EscgParams(engine=engine, mesh_shape=ms,
                                         local_kernel=lk, **kw), dom,
                              n_trials=5, n_mcs=4, chunk_mcs=2,
                              stop_on_stasis=False)

        oracle = run('pallas_fused')            # vmapped, pod-sharded
        base = run('sharded_pod', (1, 1, 1), 'fused')
        for f in ('survival', 'densities', 'stasis_mcs', 'extinction_mcs'):
            assert np.array_equal(getattr(base, f), getattr(oracle, f)), f

        legal = [(p, r, c)
                 for p in (1, 2, 4, 8) for r in (1, 2, 4) for c in (1, 2, 4)
                 if p * r * c == 8]
        rng = random.Random("fused_factorization")
        for ms in rng.sample(legal, 5):
            r = run('sharded_pod', ms, 'fused')
            assert r.n_devices == 8, ms
            assert np.array_equal(r.survival, oracle.survival), ms
            assert np.array_equal(r.densities, oracle.densities), ms
            assert np.array_equal(r.stasis_mcs, oracle.stasis_mcs), ms
            assert np.array_equal(r.extinction_mcs,
                                  oracle.extinction_mcs), ms
        print("FUSED_FACTORIZATION_INVARIANT")
    """, n_devices=8)
    assert "FUSED_FACTORIZATION_INVARIANT" in out


@pytest.mark.slow
def test_k_mcs_megakernel_factorization_invariance(subproc):
    """Acceptance property for the multi-MCS megakernel: k_mcs > 1 on
    ``sharded_pod / local_kernel='fused'`` is bit-identical to the
    single-device ``pallas_fused`` k_mcs=1 run on EVERY sampled (P, R, C)
    factorization of 8 fake devices. n_mcs=4 with chunk_mcs=3 and
    k_mcs=2 drives both grouped-scan shapes (3 = one group + remainder,
    then a bare-remainder chunk of 1); (P, 1, 1) layouts run the true
    single-pallas_call megakernel, multi-shard layouts the K-kernels-one-
    region fallback — same contract either way."""
    out = subproc("""
        import numpy as np
        from repro.core import EscgParams, dominance as dm
        from repro.core.trials import run_trials

        kw = dict(length=32, height=32, species=5, mobility=1e-3,
                  tile=(8, 8), empty=0.1, seed=17)
        dom = dm.RPSLS()

        def run(engine, ms=None, lk='jnp', k=1):
            return run_trials(EscgParams(engine=engine, mesh_shape=ms,
                                         local_kernel=lk, k_mcs=k, **kw),
                              dom, n_trials=5, n_mcs=4, chunk_mcs=3,
                              stop_on_stasis=False)

        oracle = run('pallas_fused')
        for ms in ((8, 1, 1), (2, 2, 2), (1, 2, 4), (4, 1, 2)):
            for k in (2, 3):
                r = run('sharded_pod', ms, 'fused', k)
                assert r.n_devices == 8, (ms, k)
                assert np.array_equal(r.survival, oracle.survival), (ms, k)
                assert np.array_equal(r.densities,
                                      oracle.densities), (ms, k)
                assert np.array_equal(r.stasis_mcs,
                                      oracle.stasis_mcs), (ms, k)
                assert np.array_equal(r.extinction_mcs,
                                      oracle.extinction_mcs), (ms, k)
        print("K_MCS_FACTORIZATION_INVARIANT")
    """, n_devices=8)
    assert "K_MCS_FACTORIZATION_INVARIANT" in out


@pytest.mark.slow
def test_composed_pallas_local_kernel_matches_jnp(subproc):
    """The acceptance pairing: local_kernel='pallas' inside the composed
    shard_map region is bit-identical to the jnp sweeps, for both the
    sharded and sharded_pod engines."""
    out = subproc("""
        import numpy as np
        from repro.core import EscgParams, dominance as dm, simulate
        from repro.core.trials import run_trials

        kw = dict(length=32, height=32, species=5, mobility=1e-3,
                  tile=(8, 8), empty=0.1, seed=2)
        dom = dm.RPSLS()
        a = simulate(EscgParams(engine='sharded', shard_grid=(2, 2),
                                local_kernel='jnp', mcs=3, chunk_mcs=3,
                                **kw), dom, stop_on_stasis=False)
        b = simulate(EscgParams(engine='sharded', shard_grid=(2, 2),
                                local_kernel='pallas', mcs=3, chunk_mcs=3,
                                **kw), dom, stop_on_stasis=False)
        assert np.array_equal(a.grid, b.grid)
        assert np.array_equal(a.densities, b.densities)

        rj = run_trials(EscgParams(engine='sharded_pod',
                                   mesh_shape=(2, 2, 2), **kw),
                        dom, 3, n_mcs=3, stop_on_stasis=False)
        rp = run_trials(EscgParams(engine='sharded_pod',
                                   mesh_shape=(2, 2, 2),
                                   local_kernel='pallas', **kw),
                        dom, 3, n_mcs=3, stop_on_stasis=False)
        assert np.array_equal(rj.survival, rp.survival)
        assert np.array_equal(rj.densities, rp.densities)
        assert np.array_equal(rj.extinction_mcs, rp.extinction_mcs)
        print("LOCAL_KERNEL_BIT_IDENTICAL")
    """, n_devices=8)
    assert "LOCAL_KERNEL_BIT_IDENTICAL" in out
