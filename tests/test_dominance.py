import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # hermetic container: deterministic fallback sampler
    from _propcheck import given, settings, strategies as st

from repro.core import dominance as dm


def test_rps_matrix():
    d = dm.RPS()
    assert d.shape == (4, 4)
    # 1 beats 2, 2 beats 3, 3 beats 1
    assert d[1, 2] == 1 and d[2, 3] == 1 and d[3, 1] == 1
    assert d[2, 1] == 0 and d[1, 3] == 0
    assert np.all(d[0, :] == 0) and np.all(d[:, 0] == 0)


def test_rpsls_is_tournament():
    d = dm.RPSLS()[1:, 1:]
    # every distinct pair has exactly one winner; no mutual dominance
    for i in range(5):
        assert d[i, i] == 0
        for j in range(i + 1, 5):
            assert d[i, j] + d[j, i] == 1


def test_rpsls_matches_real_game():
    """The C(5,{1,2}) embedding must reproduce all ten real RPSLS edges."""
    d = dm.RPSLS()
    R, S, L, P, K = dm.ROCK, dm.SCISSORS, dm.LIZARD, dm.PAPER, dm.SPOCK
    wins = [(R, S), (R, L), (P, R), (P, K), (S, P), (S, L), (L, P), (L, K),
            (K, R), (K, S)]
    for w, l in wins:
        assert d[w, l] == 1.0, (w, l)
        assert d[l, w] == 0.0, (w, l)


def test_zhong_ablation():
    d = dm.zhong_ablated_rpsls()
    assert d[dm.ROCK, dm.SCISSORS] == 0.0          # removed edge
    assert d[dm.ROCK, dm.LIZARD] == 1.0            # rest intact
    assert dm.RPSLS()[dm.ROCK, dm.SCISSORS] == 1.0


@given(s=st.integers(2, 12),
       offs=st.sets(st.integers(1, 11), min_size=1, max_size=4))
@settings(max_examples=40, deadline=None)
def test_circulant_rows_are_cyclic_permutations(s, offs):
    offs = {o % s for o in offs} - {0}
    if not offs:
        return
    d = dm.circulant(s, tuple(offs))[1:, 1:]
    for i in range(s):
        assert np.array_equal(np.roll(d[0], i), d[i])
    assert d.sum() == s * len(offs)


def test_csv_roundtrip():
    d = dm.park_alliance_network(0.3, 0.75, 1.0)
    d2 = dm.from_csv(dm.to_csv(d))
    np.testing.assert_allclose(d, d2, atol=1e-6)


def test_park_network_structure():
    d = dm.park_alliance_network(alpha=0.25, beta=0.6, gamma=1.0)
    m = d[1:, 1:]
    for i in range(8):
        assert m[i, (i + 1) % 8] == pytest.approx(1.0)     # gamma ring
        assert m[i, (i + 2) % 8] == pytest.approx(0.25)    # alliances
    for i in (0, 2, 4, 6):                                 # beta only in A
        assert m[i, (i + 4) % 8] == pytest.approx(0.6)
    for i in (1, 3, 5, 7):
        assert m[i, (i + 4) % 8] == pytest.approx(0.0)


def test_ablate_validates():
    with pytest.raises(ValueError):
        dm.ablate(dm.RPS(), [(0, 1)])
