import os

import jax
import numpy as np
import pytest

from repro.core import EscgParams, dominance as dm, io as io_mod, metrics
from repro.core import simulate
from repro.core.simulation import run_trials


def test_determinism_same_seed():
    p = EscgParams(length=20, height=20, species=3, mcs=30, seed=42,
                   chunk_mcs=10)
    r1, r2 = simulate(p), simulate(p)
    np.testing.assert_array_equal(r1.grid, r2.grid)
    np.testing.assert_array_equal(r1.densities, r2.densities)


def test_densities_shape_and_simplex():
    p = EscgParams(length=16, height=24, species=4, mcs=25, chunk_mcs=10,
                   empty=0.3, seed=3)
    r = simulate(p, dm.circulant(4), stop_on_stasis=False)
    assert r.densities.shape == (26, 5)
    np.testing.assert_allclose(r.densities.sum(axis=1), 1.0, atol=1e-6)
    assert r.mcs_completed == 25


def test_stasis_early_exit():
    """Single species + empties: reproduction-only fills the lattice; the
    run is in stasis from the start (<=1 species alive)."""
    p = EscgParams(length=10, height=10, species=1, mcs=500, chunk_mcs=50,
                   empty=0.5, mu=0.0, sigma=1.0, epsilon=0.0, seed=0)
    r = simulate(p, dm.from_dense(np.zeros((1, 1), np.float32)))
    assert r.stasis_mcs >= 0
    assert r.mcs_completed < 500


def test_mcs_accounting_paper_alignment():
    """numRandoms alignment: proposals_per_round is a positive multiple of
    N (paper: numRandoms = (numRandoms / N) * N)."""
    p = EscgParams(length=10, height=10, num_randoms=777, max_step=True)
    assert p.proposals_per_round == 700
    assert p.mcs_per_round == 7
    p2 = EscgParams(length=10, height=10, num_randoms=50, max_step=True)
    assert p2.proposals_per_round == 100          # at least one MCS


def test_state_io_roundtrip(tmp_path):
    p = EscgParams(length=12, height=12, species=5, mcs=10, seed=1)
    dom = dm.RPSLS()
    r = simulate(p, dom, stop_on_stasis=False)
    io_mod.save_state(str(tmp_path), p, r.grid, 10, dom)
    p2, grid2, mcs2, dom2, _ = io_mod.load_state(str(tmp_path))
    assert p2 == p
    assert mcs2 == 10
    np.testing.assert_array_equal(grid2, r.grid)
    np.testing.assert_allclose(dom2, dom)
    # paper CSV grid format round-trips as well
    g3, m3 = io_mod.import_grid_csv(os.path.join(str(tmp_path), "grid.csv"))
    np.testing.assert_array_equal(g3, r.grid)
    assert m3 == 10


def test_hooks_called_every_chunk():
    calls = []
    p = EscgParams(length=10, height=10, species=3, mcs=30, chunk_mcs=10,
                   seed=2)
    simulate(p, hooks=[lambda m, g, c: calls.append((m, c.shape))],
             stop_on_stasis=False)
    assert [c[0] for c in calls] == [10, 20, 30]
    assert all(c[1] == (10, 4) for c in calls)


def test_run_trials_vmapped():
    surv = run_trials(EscgParams(length=12, height=12, species=3, seed=9),
                      dm.RPS(), n_trials=5, n_mcs=10)
    assert surv.shape == (5, 3)
    assert surv.dtype == bool
    # 10 MCS on a 12x12 RPS grid: everyone still alive
    assert surv.all()


def test_kept_fraction_reported():
    p = EscgParams(length=16, height=16, species=3, mcs=10, seed=0,
                   engine="batched", chunk_mcs=10)
    r = simulate(p, stop_on_stasis=False)
    assert 0.5 < r.kept_fraction <= 1.0


def test_first_extinction_metric():
    hist = np.array([[0.0, 0.5, 0.5], [0.0, 0.0, 1.0], [0.0, 0.0, 1.0]])
    assert metrics.first_extinction_mcs(hist, 1) == 1
    assert metrics.first_extinction_mcs(hist, 2) == -1
