import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime.checkpoint import CheckpointManager


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (8, 16)),
                       "b": jnp.zeros((16,))},
            "step": jnp.int32(7)}


def test_roundtrip(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2)
    t = _tree()
    cm.save(7, t)
    step, got = cm.restore()
    assert step == 7
    np.testing.assert_array_equal(np.asarray(got["params"]["w"]),
                                  np.asarray(t["params"]["w"]))
    np.testing.assert_array_equal(np.asarray(got["step"]), 7)


def test_retention_and_latest(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        cm.save(s, _tree(s))
    assert cm.all_steps() == [3, 4]
    assert cm.latest_step() == 4


def test_async_save(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=3)
    cm.save(1, _tree(1), blocking=False)
    cm.wait()
    assert cm.latest_step() == 1


def test_atomicity_marker(tmp_path):
    """A directory without the COMMITTED marker is invisible."""
    cm = CheckpointManager(str(tmp_path), keep=3)
    cm.save(5, _tree())
    bad = os.path.join(str(tmp_path), "step_0000000009")
    os.makedirs(bad)
    assert cm.all_steps() == [5]


def test_restore_with_sharding_single_device(tmp_path):
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",))
    cm = CheckpointManager(str(tmp_path))
    t = _tree()
    cm.save(1, t)
    sh = {"params": {"w": NamedSharding(mesh, P()),
                     "b": NamedSharding(mesh, P())},
          "step": NamedSharding(mesh, P())}
    _, got = cm.restore(shardings=sh)
    np.testing.assert_array_equal(np.asarray(got["params"]["w"]),
                                  np.asarray(t["params"]["w"]))


def test_missing_checkpoint_raises(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        cm.restore()
