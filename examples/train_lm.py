"""Train a small LM end-to-end with the full production path: synthetic
pipeline -> sharding-ready train step -> AdamW -> checkpoint/restart loop.

Default is a ~13M-parameter granite-family model that trains in a few
minutes on this CPU container and demonstrably learns the synthetic
structure (loss drops well below ln(vocab)). For the ~100M variant:

    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300

(larger presets are CPU-hours; the assigned full configs are exercised via
the dry-run instead).
"""
import argparse

from repro.launch import train as train_cli
import sys


PRESETS = {
    "13m": ["--d_model", "256", "--layers", "8", "--heads", "8",
            "--vocab", "4096", "--batch", "8", "--seq", "128"],
    "100m": ["--d_model", "640", "--layers", "12", "--heads", "10",
             "--vocab", "16384", "--batch", "8", "--seq", "256"],
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=sorted(PRESETS), default="13m")
    ap.add_argument("--steps", type=int, default=300)
    args, rest = ap.parse_known_args()

    sys.argv = (["train"] + ["--arch", "granite-3-8b", "--reduced"]
                + PRESETS[args.preset]
                + ["--steps", str(args.steps), "--ckpt_dir",
                   "out/train_lm", "--ckpt_every", "100"] + rest)
    train_cli.main()


if __name__ == "__main__":
    main()
