"""Park, Chen & Szolnoki (2023) eight-species alliances (paper §4.3.2,
Figs 4.8-4.13) + the Cliff & Sinadjan mobility extension (Appendix C).

    PYTHONPATH=src python examples/park_alliances.py \
        --alpha 0.15 --beta 0.75 --L 48 --trials 8
    PYTHONPATH=src python examples/park_alliances.py --mobility 1e-4 ...

Reports per-species survival probabilities and the survivor-count
histogram over vmapped IID trials; with --mobility > 0 it reproduces the
companion paper's central claim that mobility changes the phase behaviour.
"""
import argparse

import numpy as np

from repro.core.park import survival_probabilities


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--alpha", type=float, default=0.15)
    ap.add_argument("--beta", type=float, default=0.75)
    ap.add_argument("--gamma", type=float, default=1.0)
    ap.add_argument("--L", type=int, default=48)
    ap.add_argument("--trials", type=int, default=8)
    ap.add_argument("--mcs", type=int, default=0,
                    help="0 -> Park protocol (L^2)")
    ap.add_argument("--mobility", type=float, default=0.0,
                    help=">0 enables the companion-paper extension")
    args = ap.parse_args()

    mcs = args.mcs or args.L * args.L
    ps, hist = survival_probabilities(
        args.alpha, args.beta, args.gamma, L=args.L, n_trials=args.trials,
        mcs=mcs, mobility=args.mobility)

    tag = (f"alpha={args.alpha} beta={args.beta} gamma={args.gamma} "
           f"L={args.L} mcs={mcs} mobility={args.mobility}")
    print(f"Park alliances: {tag}")
    print("survival probability per species:")
    for i, p in enumerate(ps, start=1):
        bar = "#" * int(p * 40)
        print(f"  s{i}: {p:5.2f} {bar}")
    print("survivor-count histogram:",
          " ".join(f"{i}:{v:.2f}" for i, v in enumerate(hist) if v > 0))
    print(f"species-5 extinction probability: {1 - ps[4]:.3f} "
          f"(paper Fig 4.11-4.13 studies this across alpha)")
    if args.mobility > 0:
        print("mobility > 0: the companion paper shows this collapses "
              "Park et al.'s phase structure — compare against "
              "--mobility 0 at the same seed")


if __name__ == "__main__":
    main()
