"""End-to-end production driver of the paper's kind (simulation): a long
ESCG run with maxStep-style chunking, periodic checkpointing, snapshot
export, stasis early-exit and crash-resume — the workflow behind the
dissertation's 100k-MCS experiments.

    PYTHONPATH=src python examples/escg_longrun.py --mcs 5000
    PYTHONPATH=src python examples/escg_longrun.py --mcs 8000   # resumes

(For the cluster-scale variant the same loop runs with
repro.core.sharded.make_sharded_simulation on the production mesh —
see tests/test_sharded_engine.py.)
"""
import argparse
import os
import time

import numpy as np

from repro.core import EscgParams, dominance, io, scenarios, simulate

OUT = "out/longrun"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--L", type=int, default=128)
    ap.add_argument("--mcs", type=int, default=5000)
    ap.add_argument("--engine", type=str, default="sublattice")
    ap.add_argument("--species", type=int, default=5)
    args = ap.parse_args()

    dom = dominance.circulant(args.species, (1, 2))
    start_mcs = 0
    grid0 = key = None
    if os.path.exists(os.path.join(OUT, "state.npz")):
        params, grid0, start_mcs, dom, key_arr = io.load_state(OUT)
        print(f"[longrun] resuming at MCS {start_mcs}")
        params = params.replace(mcs=max(args.mcs - start_mcs, 0))
        import jax
        key = (jax.numpy.asarray(key_arr) if key_arr is not None else
               jax.random.fold_in(jax.random.PRNGKey(0), start_mcs))
    else:
        params = EscgParams(length=args.L, height=args.L,
                            species=args.species, mobility=1e-5,
                            mcs=args.mcs, chunk_mcs=500,
                            engine=args.engine,
                            tile=(8, 16), seed=3, out_dir=OUT)

    ckpt_state = {"last": start_mcs}

    def checkpoint_hook(mcs_done, grid, counts):
        total = start_mcs + mcs_done
        if total - ckpt_state["last"] >= 1000:
            io.save_state(OUT, params.replace(mcs=args.mcs),
                          np.asarray(grid), total, np.asarray(dom))
            io.save_snapshot(OUT, np.asarray(grid), total)
            ckpt_state["last"] = total
            print(f"[longrun] checkpoint @ MCS {total}")

    t0 = time.time()
    # scenario-first invocation (DESIGN.md §10): decompose the (possibly
    # checkpoint-loaded) flat params and keep the explicit dominance net
    sc, eng_cfg, run_cfg = scenarios.decompose(params)
    res = simulate(sc, dom, grid0=grid0, key=key,
                   hooks=[checkpoint_hook], engine=eng_cfg, run=run_cfg)
    dt = time.time() - t0
    total = start_mcs + res.mcs_completed
    io.save_state(OUT, params.replace(mcs=args.mcs), res.grid, total,
                  np.asarray(dom))
    ups = res.mcs_completed * params.n_cells / max(dt, 1e-9)
    print(f"[longrun] MCS {start_mcs}->{total} in {dt:.1f}s "
          f"({ups/1e6:.2f} M elementary updates/s)")
    if res.stasis_mcs >= 0:
        print(f"[longrun] stasis at MCS {start_mcs + res.stasis_mcs}")
    print("[longrun] densities:", np.round(res.densities[-1], 4))


if __name__ == "__main__":
    main()
