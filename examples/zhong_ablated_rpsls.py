"""Replication of Zhong et al. (2022) Fig 2 (paper §3.1.2, Figs 3.2/3.3):
ablated RPSLS — remove Rock-crushes-Scissors and watch Paper go extinct
within a few hundred MCS, followed by the Rock-survival bifurcation.

    PYTHONPATH=src python examples/zhong_ablated_rpsls.py [--mcs 3000]

The paper's long-run finding (Cliff 2025): the apparent steady state decays
at much longer horizons — push --mcs up to probe it.
"""
import argparse

from repro.core import EngineConfig, RunConfig, dominance, io, metrics
from repro.core import make_scenario, simulate

NAMES = {dominance.ROCK: "Rock", dominance.SCISSORS: "Scissors",
         dominance.LIZARD: "Lizard", dominance.PAPER: "Paper",
         dominance.SPOCK: "Spock"}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--L", type=int, default=100)
    ap.add_argument("--mcs", type=int, default=3000)
    ap.add_argument("--engine", type=str, default="batched")
    ap.add_argument("--seed", type=int, default=11)
    args = ap.parse_args()

    # the whole study is one registered scenario (DESIGN.md §10): physics
    # (ablated dominance network, mobility, S=5) come from the registry
    res = simulate(make_scenario("zhong_density"),
                   engine=EngineConfig(engine=args.engine),
                   run=RunConfig(length=args.L, height=args.L,
                                 mcs=args.mcs, chunk_mcs=500,
                                 seed=args.seed,
                                 out_dir="out/zhong"),
                   stop_on_stasis=False)

    print(f"L={args.L}, {args.mcs} MCS, engine={args.engine}")
    for sp in range(1, 6):
        ext = metrics.first_extinction_mcs(res.densities, sp)
        end = res.densities[-1][sp]
        status = f"extinct at MCS {ext}" if ext >= 0 else \
            f"alive (density {end:.3f})"
        print(f"  {NAMES[sp]:<9s} {status}")

    ext_paper = metrics.first_extinction_mcs(res.densities, dominance.PAPER)
    print(f"\nZhong et al. claim: Paper extinct within 200-600 MCS at "
          f"L=200 (faster for smaller L). Here: {ext_paper}.")
    io.export_densities_csv("out/zhong/densities.csv", res.densities)
    print("density trace -> out/zhong/densities.csv")


if __name__ == "__main__":
    main()
