"""Quickstart: Reichenbach–Mobilia–Frey rock-paper-scissors spirals
(paper Fig 1.1) in ~30 lines of public API.

    PYTHONPATH=src python examples/quickstart.py

Runs a 128x128 three-species ESCG at low mobility, prints density traces
and an ASCII snapshot; saves the lattice + densities under out/quickstart.
"""
import numpy as np

from repro.core import EscgParams, dominance, io, simulate

GLYPHS = " RPS45678"


def ascii_lattice(grid: np.ndarray, step: int = 4) -> str:
    return "\n".join("".join(GLYPHS[v] for v in row[::step])
                     for row in grid[::step])


def main() -> None:
    params = EscgParams(
        length=128, height=128, species=3,
        mobility=3e-5,                  # below the RMF threshold -> spirals
        empty=0.1, mcs=400, chunk_mcs=100,
        engine="batched", seed=0, out_dir="out/quickstart")
    dom = dominance.RPS()

    def report(mcs_done, grid, counts):
        dens = counts[-1] / counts[-1].sum()
        print(f"MCS {mcs_done:5d}  empty={dens[0]:.3f} "
              f"R={dens[1]:.3f} P={dens[2]:.3f} S={dens[3]:.3f}")

    result = simulate(params, dom, hooks=[report])
    print("\nFinal lattice (1:4 downsample):")
    print(ascii_lattice(result.grid))
    io.save_state(params.out_dir, params, result.grid,
                  result.mcs_completed, dom)
    io.export_densities_csv(f"{params.out_dir}/densities.csv",
                            result.densities)
    print(f"\nsaved state + densities to {params.out_dir}/")
    assert (result.densities[-1][1:] > 0).all(), "coexistence expected"
    print("all three species coexist — RMF low-mobility regime replicated")


if __name__ == "__main__":
    main()
