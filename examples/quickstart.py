"""Quickstart: Reichenbach–Mobilia–Frey rock-paper-scissors spirals
(paper Fig 1.1) in ~30 lines of public API.

    PYTHONPATH=src python examples/quickstart.py

Runs a 128x128 three-species ESCG at low mobility via the scenario-first
API (DESIGN.md §10): physics from the registered ``park3`` preset, run
control from a ``RunConfig``. The preset's declared observables stream
through the device ring buffer (DESIGN.md §11), so the result carries an
interface-length trace alongside the densities. Prints density traces and
an ASCII snapshot; saves the lattice + densities under out/quickstart.
"""
import numpy as np

from repro.core import (EngineConfig, RunConfig, compose, io,
                        make_scenario, simulate)

GLYPHS = " RPS45678"


def ascii_lattice(grid: np.ndarray, step: int = 4) -> str:
    return "\n".join("".join(GLYPHS[v] for v in row[::step])
                     for row in grid[::step])


def main() -> None:
    scenario = make_scenario("park3", empty=0.1)   # RMF spirals, S=3
    engine = EngineConfig(engine="batched")
    run = RunConfig(length=128, height=128, mcs=400, chunk_mcs=100,
                    seed=0, out_dir="out/quickstart")

    def report(mcs_done, grid, counts):
        dens = counts[-1] / counts[-1].sum()
        print(f"MCS {mcs_done:5d}  empty={dens[0]:.3f} "
              f"R={dens[1]:.3f} P={dens[2]:.3f} S={dens[3]:.3f}")

    result = simulate(scenario, engine=engine, run=run, hooks=[report])
    print("\nFinal lattice (1:4 downsample):")
    print(ascii_lattice(result.grid))
    params = compose(scenario, engine, run)
    io.save_state(run.out_dir, params, result.grid,
                  result.mcs_completed, scenario.dominance())
    io.export_densities_csv(f"{run.out_dir}/densities.csv",
                            result.densities)
    print(f"\nsaved state + densities to {run.out_dir}/")
    assert (result.densities[-1][1:] > 0).all(), "coexistence expected"
    print("all three species coexist — RMF low-mobility regime replicated")
    # the preset's streamed observables (DESIGN.md §11): interface length
    # tracks the spiral-boundary density, computed on-device every MCS
    iface = result.observables["interface_length"][:, 0]
    print(f"interface length {iface[0]:.3f} -> {iface[-1]:.3f} "
          f"({len(iface)} MCS on-device trace)")


if __name__ == "__main__":
    main()
