"""State-space (Mamba) blocks.

Mamba-1 (falcon-mamba-7b): selective scan over a diagonal SSM, computed with
a chunked associative scan (sequential across chunks, parallel within) — the
same schedule idea as the ESCG sublattice engine (DESIGN.md §9).
Mamba-2 (zamba2-7b): SSD dual form — scalar-per-head decay, chunked matmul
formulation (MXU-friendly).

Both provide single-token decode recurrences for serving.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .spec import ParamSpec


# ------------------------------- mamba-1 --------------------------------- #

def mamba1_specs(cfg) -> dict:
    d, di, n, cv = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    dtr = max(1, d // 16)
    dt = cfg.param_dtype
    return {
        "in_proj": ParamSpec((d, 2 * di), ("embed", "inner2"), dtype=dt),
        "conv_w": ParamSpec((cv, di), ("conv", "inner"), dtype=dt),
        "conv_b": ParamSpec((di,), ("inner",), init="zeros", dtype=dt),
        "x_dbc": ParamSpec((di, dtr + 2 * n), ("inner", "dbc"), dtype=dt),
        "dt_proj": ParamSpec((dtr, di), ("dt_rank", "inner"), dtype=dt),
        "dt_bias": ParamSpec((di,), ("inner",), init="zeros", dtype=dt),
        "a_log": ParamSpec((di, n), ("inner", "state"), init="ones",
                           dtype="float32"),
        "d_skip": ParamSpec((di,), ("inner",), init="ones", dtype="float32"),
        "out_proj": ParamSpec((di, d), ("inner", "embed"), dtype=dt),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: jax.Array = None) -> jax.Array:
    """Depthwise causal conv. x: (B,S,di), w: (cv,di). state: (B,cv-1,di)."""
    cv = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (cv - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i].astype(x.dtype)
              for i in range(cv))
    return out + b.astype(x.dtype)


def _ssm_scan_chunked(a: jax.Array, bu: jax.Array, h0: jax.Array,
                      chunk: int) -> Tuple[jax.Array, jax.Array]:
    """h_t = a_t * h_{t-1} + bu_t, diagonal. a, bu: (B, S, di, n) f32.
    Returns (h over all t, final h). Chunked: associative scan within a
    chunk, lax.scan across chunks. (Reference/spec path — materializes the
    full (B,S,di,n) state; the layer uses the fused variant below.)"""
    b, s, di, n = a.shape
    while s % chunk:
        chunk //= 2
    nc = s // chunk
    a_c = a.reshape(b, nc, chunk, di, n).transpose(1, 0, 2, 3, 4)
    bu_c = bu.reshape(b, nc, chunk, di, n).transpose(1, 0, 2, 3, 4)

    def combine(l, r):
        al, ul = l
        ar, ur = r
        return al * ar, ul * ar + ur

    def step(h, xs):
        ac, uc = xs
        aa, uu = jax.lax.associative_scan(combine, (ac, uc), axis=1)
        h_all = aa * h[:, None] + uu
        return h_all[:, -1], h_all

    h_last, h_all = jax.lax.scan(step, h0, (a_c, bu_c))
    h_all = h_all.transpose(1, 0, 2, 3, 4).reshape(b, s, di, n)
    return h_all, h_last


def _ssm_scan_fused(xc: jax.Array, dt: jax.Array, b_ssm: jax.Array,
                    c_ssm: jax.Array, a: jax.Array, d_skip: jax.Array,
                    h0: jax.Array, chunk: int
                    ) -> Tuple[jax.Array, jax.Array]:
    """Fused selective scan: y_t = C_t · h_t + D x_t with
    h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t, WITHOUT ever materializing a
    (B, S, di, n) tensor in HBM (§Perf H1): the (B, Q, di, n) decay/input
    products live only inside each chunk's scan body.

    xc, dt: (B, S, di) f32;  b_ssm, c_ssm: (B, S, n) f32;  a: (di, n);
    h0: (B, di, n). Returns (y (B, S, di) f32, h_last).
    """
    b, s, di = xc.shape
    n = a.shape[-1]
    while s % chunk:
        chunk //= 2
    nc = s // chunk

    def to_chunks(t):
        return t.reshape(b, nc, chunk, *t.shape[2:]).swapaxes(0, 1)

    def combine(l, r):
        al, ul = l
        ar, ur = r
        return al * ar, ul * ar + ur

    @jax.checkpoint       # recompute hq in backward: without this the scan
    def step(h, xs):      # saves (B,Q,di,n) residuals per chunk = the full
        xq, dtq, bq, cq = xs                  # state tensor again (§Perf H1)
        da = jnp.exp(dtq[..., None] * a)           # (B,Q,di,n) transient
        bu = (dtq * xq)[..., None] * bq[:, :, None, :]
        aa, uu = jax.lax.associative_scan(combine, (da, bu), axis=1)
        hq = aa * h[:, None] + uu                  # (B,Q,di,n) transient
        yq = jnp.einsum("bqdn,bqn->bqd", hq, cq)
        return hq[:, -1], yq

    h_last, y = jax.lax.scan(
        step, h0, (to_chunks(xc), to_chunks(dt), to_chunks(b_ssm),
                   to_chunks(c_ssm)))
    y = y.swapaxes(0, 1).reshape(b, s, di)
    return y + xc * d_skip, h_last


def mamba1_forward(p: dict, x: jax.Array, cfg,
                   state: Dict[str, jax.Array] = None
                   ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: (B, S, d). Returns (y, new_state). state carries conv + ssm for
    decode; pass None for training (zero init, state returned anyway)."""
    bsz, s, _ = x.shape
    di, n = cfg.d_inner, cfg.ssm_state
    dtr = max(1, cfg.d_model // 16)
    ct = x.dtype

    xz = x @ p["in_proj"].astype(ct)
    xi, z = jnp.split(xz, 2, axis=-1)

    conv_state = None if state is None else state["conv"]
    xc = _causal_conv(xi, p["conv_w"], p["conv_b"], conv_state)
    new_conv = jnp.concatenate(
        [conv_state.astype(ct) if conv_state is not None else
         jnp.zeros((bsz, cfg.ssm_conv - 1, di), ct), xi],
        axis=1)[:, -(cfg.ssm_conv - 1):, :]
    xc = jax.nn.silu(xc)

    dbc = xc @ p["x_dbc"].astype(ct)
    dt_r, b_ssm, c_ssm = jnp.split(dbc, [dtr, dtr + n], axis=-1)
    dt = jax.nn.softplus(dt_r @ p["dt_proj"].astype(ct)
                         + p["dt_bias"].astype(ct)).astype(jnp.float32)
    a = -jnp.exp(p["a_log"])                           # (di, n), negative

    h0 = (jnp.zeros((bsz, di, n), jnp.float32) if state is None
          else state["ssm"])
    y, h_last = _ssm_scan_fused(
        xc.astype(jnp.float32), dt, b_ssm.astype(jnp.float32),
        c_ssm.astype(jnp.float32), a, p["d_skip"], h0, cfg.ssm_chunk)
    y = y.astype(ct) * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(ct)
    return out, {"conv": new_conv, "ssm": h_last}


# ------------------------------- mamba-2 --------------------------------- #

def mamba2_specs(cfg) -> dict:
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    nh = cfg.ssm_heads
    cv = cfg.ssm_conv
    dt = cfg.param_dtype
    d_conv_in = di + 2 * n                      # x, B, C share the conv
    return {
        "in_proj": ParamSpec((d, 2 * di + 2 * n + nh),
                             ("embed", "inner_zxbcdt"), dtype=dt),
        "conv_w": ParamSpec((cv, d_conv_in), ("conv", "inner"), dtype=dt),
        "conv_b": ParamSpec((d_conv_in,), ("inner",), init="zeros", dtype=dt),
        "a_log": ParamSpec((nh,), ("heads",), init="ones", dtype="float32"),
        "dt_bias": ParamSpec((nh,), ("heads",), init="zeros",
                             dtype="float32"),
        "d_skip": ParamSpec((nh,), ("heads",), init="ones", dtype="float32"),
        "norm_scale": ParamSpec((di,), ("inner",), init="ones", dtype=dt),
        "out_proj": ParamSpec((di, d), ("inner", "embed"), dtype=dt),
    }


def _segsum_decay(log_a: jax.Array) -> jax.Array:
    """L[i, j] = exp(sum_{j<t<=i} log_a_t) for j <= i else 0.
    log_a: (..., Q). Returns (..., Q, Q)."""
    q = log_a.shape[-1]
    cs = jnp.cumsum(log_a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]          # (..., i, j)
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, jnp.exp(diff), 0.0)


def mamba2_forward(p: dict, x: jax.Array, cfg,
                   state: Dict[str, jax.Array] = None
                   ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """SSD chunked form. x: (B,S,d) -> (y, state)."""
    bsz, s, _ = x.shape
    di, n, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    ph = di // nh                                      # head dim
    ct = x.dtype

    proj = x @ p["in_proj"].astype(ct)
    z, xbc, dt_in = jnp.split(proj, [di, 2 * di + 2 * n], axis=-1)
    conv_state = None if state is None else state["conv"]
    xbc_c = jax.nn.silu(_causal_conv(xbc, p["conv_w"], p["conv_b"],
                                     conv_state))
    new_conv = jnp.concatenate(
        [conv_state.astype(ct) if conv_state is not None else
         jnp.zeros((bsz, cfg.ssm_conv - 1, di + 2 * n), ct), xbc],
        axis=1)[:, -(cfg.ssm_conv - 1):, :]
    xi, b_ssm, c_ssm = jnp.split(xbc_c, [di, di + n], axis=-1)

    dt = jax.nn.softplus(dt_in.astype(jnp.float32)
                         + p["dt_bias"])               # (B,S,nh)
    a = -jnp.exp(p["a_log"])                           # (nh,)
    log_da = dt * a                                    # (B,S,nh) negative
    xh = xi.reshape(bsz, s, nh, ph).astype(jnp.float32)
    bf = b_ssm.astype(jnp.float32)                     # (B,S,n)
    cf = c_ssm.astype(jnp.float32)
    dtx = xh * dt[..., None]                           # dt-weighted input

    q = cfg.ssm_chunk
    while s % q:
        q //= 2
    nc = s // q

    la = log_da.reshape(bsz, nc, q, nh)
    xq = dtx.reshape(bsz, nc, q, nh, ph)
    bq = bf.reshape(bsz, nc, q, n)
    cq = cf.reshape(bsz, nc, q, n)

    # intra-chunk: Y = (C B^T ∘ L) X
    lmat = _segsum_decay(la.transpose(0, 1, 3, 2))     # (B,nc,nh,Q,Q)
    cb = jnp.einsum("bcin,bcjn->bcij", cq, bq)         # (B,nc,Q,Q)
    y_intra = jnp.einsum("bchij,bcij,bcjhp->bcihp",
                         lmat, cb, xq)

    # chunk states: S_c = sum_j decay_to_end_j * B_j X_j^T  (B,nc,nh,n,p)
    cum = jnp.cumsum(la, axis=2)
    decay_end = jnp.exp(cum[:, :, -1:, :] - cum)       # (B,nc,Q,nh)
    s_chunk = jnp.einsum("bcjh,bcjn,bcjhp->bchnp", decay_end, bq, xq)

    # inter-chunk recurrence over c: H_{c} = decay_chunk_c * H_{c-1} + S_c
    chunk_decay = jnp.exp(cum[:, :, -1, :])            # (B,nc,nh)
    h0 = (jnp.zeros((bsz, nh, n, ph), jnp.float32) if state is None
          else state["ssm"])

    def step(h, xs):
        dc, sc = xs                                    # (B,nh), (B,nh,n,p)
        h_in = h
        h = h * dc[:, :, None, None] + sc
        return h, h_in

    (h_last, h_prevs) = jax.lax.scan(
        step, h0, (chunk_decay.transpose(1, 0, 2),
                   s_chunk.transpose(1, 0, 2, 3, 4)))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)         # (B,nc,nh,n,p)

    # inter-chunk output: C_t decay_from_start_t H_{c-1}
    decay_start = jnp.exp(cum)                         # (B,nc,Q,nh)
    y_inter = jnp.einsum("bcin,bcih,bchnp->bcihp",
                         cq, decay_start, h_prevs)

    y = (y_intra + y_inter).reshape(bsz, s, nh, ph)
    y = y + xh * p["d_skip"][None, None, :, None]
    y = y.reshape(bsz, s, di).astype(ct)
    # gated RMSNorm (mamba2)
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    yf = yf * jax.lax.rsqrt(var + 1e-6) * p["norm_scale"].astype(jnp.float32)
    out = yf.astype(ct) @ p["out_proj"].astype(ct)
    return out, {"conv": new_conv, "ssm": h_last}
