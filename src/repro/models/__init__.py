"""Model stack for the assigned architectures (DESIGN.md §9)."""
from . import common, encdec, moe, registry, spec, ssm, transformer
from .registry import Model, build_model
