"""Decoder-only LM supporting the dense / moe / ssm / hybrid / vlm families,
with scan-over-layers + remat, KV/SSM caches, prefill and decode steps.

One code path serves minitron-4b, granite-3-8b, qwen1.5-32b, yi-9b,
pixtral-12b (text backbone + stub image-embedding prefix), kimi-k2, grok-1,
falcon-mamba-7b and zamba2-7b.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import common, moe as moe_mod, ssm as ssm_mod
from ..parallel.ctx import constrain
from .spec import ParamSpec, stack_layers

AUX_LOSS_WEIGHT = 0.01


# ------------------------------ param specs ------------------------------ #

def _layer_specs(cfg) -> dict:
    if cfg.family == "ssm":
        return {"norm": common.rmsnorm_spec(cfg.d_model, cfg.param_dtype),
                "mamba": (ssm_mod.mamba1_specs(cfg) if cfg.mamba_version == 1
                          else ssm_mod.mamba2_specs(cfg))}
    if cfg.family == "hybrid":
        return {"norm": common.rmsnorm_spec(cfg.d_model, cfg.param_dtype),
                "mamba": ssm_mod.mamba2_specs(cfg)}
    block = {
        "ln1": common.rmsnorm_spec(cfg.d_model, cfg.param_dtype),
        "attn": common.attn_specs(cfg),
        "ln2": common.rmsnorm_spec(cfg.d_model, cfg.param_dtype),
    }
    if cfg.family == "moe":
        block["moe"] = moe_mod.moe_specs(cfg)
    else:
        block["mlp"] = common.mlp_specs(cfg)
    return block


def build_specs(cfg) -> dict:
    specs: Dict[str, Any] = {
        "embed": {"tokens": ParamSpec((cfg.vocab_padded, cfg.d_model),
                                      ("vocab", "embed"),
                                      dtype=cfg.param_dtype)},
        "layers": stack_layers(_layer_specs(cfg), cfg.n_layers),
        "final_norm": common.rmsnorm_spec(cfg.d_model, cfg.param_dtype),
    }
    if not cfg.tie_embeddings:
        specs["unembed"] = ParamSpec((cfg.d_model, cfg.vocab_padded),
                                     ("embed", "vocab"),
                                     dtype=cfg.param_dtype)
    if cfg.family == "hybrid":
        # zamba2: ONE shared attention block reused every `attn_every` layers
        specs["shared_attn"] = {
            "ln1": common.rmsnorm_spec(cfg.d_model, cfg.param_dtype),
            "attn": common.attn_specs(cfg),
            "ln2": common.rmsnorm_spec(cfg.d_model, cfg.param_dtype),
            "mlp": common.mlp_specs(cfg),
        }
    return specs


# ------------------------------- caches ---------------------------------- #

def cache_specs(cfg, batch: int, max_len: int) -> dict:
    """Abstract cache layout for serving (ShapeDtypeStruct-compatible)."""
    ct = cfg.compute_dtype
    kv, hd = cfg.n_kv, cfg.head_dim
    if cfg.family == "ssm":
        di, n, cv = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
        extra = 2 * cfg.ssm_state if cfg.mamba_version == 2 else 0
        return {
            "conv": ParamSpec((cfg.n_layers, batch, cv - 1, di + extra),
                              ("layers", "batch", None, "inner"), dtype=ct),
            "ssm": (ParamSpec((cfg.n_layers, batch, di, n),
                              ("layers", "batch", "inner", "state"),
                              dtype="float32") if cfg.mamba_version == 1 else
                    ParamSpec((cfg.n_layers, batch, cfg.ssm_heads, n,
                               cfg.d_inner // cfg.ssm_heads),
                              ("layers", "batch", "heads", "state", None),
                              dtype="float32")),
            "len": ParamSpec((), (), init="zeros", dtype="int32"),
        }
    if cfg.family == "hybrid":
        n_apps = cfg.n_layers // cfg.attn_every
        di, n, cv = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
        return {
            "conv": ParamSpec((cfg.n_layers, batch, cv - 1, di + 2 * n),
                              ("layers", "batch", None, "inner"), dtype=ct),
            "ssm": ParamSpec((cfg.n_layers, batch, cfg.ssm_heads, n,
                              cfg.d_inner // cfg.ssm_heads),
                             ("layers", "batch", "heads", "state", None),
                             dtype="float32"),
            "k": ParamSpec((n_apps, batch, max_len, kv, hd),
                           ("layers", "batch", "kv_seq", "kv_heads",
                            "head_dim"), dtype=ct),
            "v": ParamSpec((n_apps, batch, max_len, kv, hd),
                           ("layers", "batch", "kv_seq", "kv_heads",
                            "head_dim"), dtype=ct),
            "len": ParamSpec((), (), init="zeros", dtype="int32"),
        }
    return {
        "k": ParamSpec((cfg.n_layers, batch, max_len, kv, hd),
                       ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
                       dtype=ct),
        "v": ParamSpec((cfg.n_layers, batch, max_len, kv, hd),
                       ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
                       dtype=ct),
        "len": ParamSpec((), (), init="zeros", dtype="int32"),
    }


# ------------------------------- forward --------------------------------- #

def scan_or_loop(body, carry, xs, n: int, use_scan: bool):
    """lax.scan when use_scan else an unrolled python loop (used by the
    dry-run's loop-corrected cost analysis: XLA HloCostAnalysis counts a
    while-loop body once, so scanned modules undercount FLOPs/bytes by ~n)."""
    if use_scan:
        return jax.lax.scan(body, carry, xs)
    ys = []
    for i in range(n):
        x_i = (None if xs is None
               else jax.tree.map(lambda a: a[i], xs))
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return carry, ys


def _attn_block(cfg, p, x, positions, k_cache=None, v_cache=None,
                cache_len=None):
    """Pre-norm attention block. Returns (residual_out, k, v) where k/v are
    the UPDATED caches in decode mode and this block's fresh k/v otherwise."""
    h = common.rmsnorm(x, p["ln1"])
    q, k, v = common.qkv_proj(p["attn"], h, cfg)
    q = common.rotary(q, positions, cfg.rope_theta)
    k = common.rotary(k, positions, cfg.rope_theta)
    if k_cache is not None:
        # decode: write this step's k/v at `cache_len`, attend over cache
        k = jax.lax.dynamic_update_slice(
            k_cache, k.astype(k_cache.dtype), (0, cache_len, 0, 0))
        v = jax.lax.dynamic_update_slice(
            v_cache, v.astype(v_cache.dtype), (0, cache_len, 0, 0))
        y = common.gqa_attention(
            q, k, v, causal=False, q_offset=cache_len,
            kv_len=cache_len + q.shape[1],
            chunk=cfg.attn_chunk if k.shape[1] > cfg.attn_chunk else 0)
    else:
        y = common.gqa_attention(
            q, k, v, causal=True,
            chunk=cfg.attn_chunk if q.shape[1] > cfg.attn_chunk else 0)
    out = x + common.attn_out(p["attn"], y)
    return out, k, v


def _mixer_block(cfg, p, x, positions, cache_slice, mode: str):
    """One scanned layer. mode: 'train' | 'prefill' | 'decode'.
    Returns (x, new_cache_slice, aux)."""
    aux = jnp.float32(0.0)
    if cfg.family in ("ssm", "hybrid"):
        h = common.rmsnorm(x, p["norm"])
        fwd = (ssm_mod.mamba1_forward
               if cfg.family == "ssm" and cfg.mamba_version == 1
               else ssm_mod.mamba2_forward)
        state = None if mode == "train" else cache_slice
        y, new_state = fwd(p["mamba"], h, cfg, state)
        return x + y, new_state, aux

    if mode == "decode":
        x, k, v = _attn_block(cfg, p, x, positions,
                              k_cache=cache_slice["k"],
                              v_cache=cache_slice["v"],
                              cache_len=cache_slice["len"])
        new_cache = {"k": k, "v": v, "len": cache_slice["len"]}
    else:
        x, k, v = _attn_block(cfg, p, x, positions)
        new_cache = {"k": k, "v": v} if mode == "prefill" else None

    h = common.rmsnorm(x, p["ln2"])
    if cfg.family == "moe":
        y, aux = moe_mod.moe_layer(p["moe"], h, cfg)
    else:
        y = common.mlp(p["mlp"], h)
    return x + y, new_cache, aux


def _run_layers(cfg, params, x, positions, cache, mode: str):
    """Scan over the layer stack; returns (x, new_cache, aux_sum)."""
    layers = params["layers"]

    def body(carry, xs):
        h, aux = carry
        lp, cs = xs
        h = constrain(h, "act_batch", "act_seq", None)
        h, new_cs, a = _mixer_block(cfg, lp, h, positions, cs, mode)
        h = constrain(h, "act_batch", "act_seq", None)
        return (h, aux + a), new_cs

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)

    if cfg.family == "hybrid":
        return _run_hybrid(cfg, params, x, positions, cache, mode, body)

    if mode == "decode":
        if cfg.family == "ssm":
            cache_xs = {"conv": cache["conv"], "ssm": cache["ssm"]}
        else:
            cache_xs = {"k": cache["k"], "v": cache["v"],
                        "len": jnp.broadcast_to(cache["len"],
                                                (cfg.n_layers,))}
    else:                                   # train / prefill: build fresh
        cache_xs = None

    (x, aux), new_cs = scan_or_loop(body, (x, jnp.float32(0.0)),
                                    (layers, cache_xs), cfg.n_layers,
                                    cfg.scan_layers)
    new_cache = None
    if mode == "decode":
        if cfg.family == "ssm":
            new_cache = {"conv": new_cs["conv"], "ssm": new_cs["ssm"],
                         "len": cache["len"] + positions.shape[-1]}
        else:
            new_cache = {"k": new_cs["k"], "v": new_cs["v"],
                         "len": cache["len"] + positions.shape[-1]}
    elif mode == "prefill":
        s = x.shape[1]
        if cfg.family == "ssm":
            new_cache = {"conv": new_cs["conv"], "ssm": new_cs["ssm"],
                         "len": jnp.int32(s)}
        else:
            new_cache = {"k": new_cs["k"], "v": new_cs["v"],
                         "len": jnp.int32(s)}
    return x, new_cache, aux


def _run_hybrid(cfg, params, x, positions, cache, mode, body):
    """zamba2: groups of `attn_every` mamba layers, each followed by the
    SHARED attention block (weights reused, per-application KV cache)."""
    shared = params["shared_attn"]
    every = cfg.attn_every
    n_full = cfg.n_layers // every
    rest = cfg.n_layers - n_full * every
    layers = params["layers"]
    aux = jnp.float32(0.0)

    def slice_layers(tree, lo, hi):
        return jax.tree.map(lambda a: a[lo:hi], tree)

    new_conv, new_ssm, new_k, new_v = [], [], [], []
    for gi in range(n_full):
        lp = slice_layers(layers, gi * every, (gi + 1) * every)
        cs = None
        if mode == "decode":
            cs = {"conv": cache["conv"][gi * every:(gi + 1) * every],
                  "ssm": cache["ssm"][gi * every:(gi + 1) * every]}
        (x, aux), ncs = scan_or_loop(body, (x, aux), (lp, cs), every,
                                     cfg.scan_layers)
        if mode != "train":
            new_conv.append(ncs["conv"])
            new_ssm.append(ncs["ssm"])
        # shared attention application gi
        if mode == "decode":
            x, k, v = _attn_block(cfg, shared, x, positions,
                                  k_cache=cache["k"][gi],
                                  v_cache=cache["v"][gi],
                                  cache_len=cache["len"])
            new_k.append(k)
            new_v.append(v)
        else:
            x, k, v = _attn_block(cfg, shared, x, positions)
            if mode == "prefill":
                new_k.append(k)
                new_v.append(v)
    if rest:
        lp = slice_layers(layers, n_full * every, cfg.n_layers)
        cs = None
        if mode == "decode":
            cs = {"conv": cache["conv"][n_full * every:],
                  "ssm": cache["ssm"][n_full * every:]}
        (x, aux), ncs = scan_or_loop(body, (x, aux), (lp, cs), rest,
                                     cfg.scan_layers)
        if mode != "train":
            new_conv.append(ncs["conv"])
            new_ssm.append(ncs["ssm"])

    new_cache = None
    if mode != "train":
        s = positions.shape[-1]
        new_cache = {
            "conv": jnp.concatenate(new_conv, 0),
            "ssm": jnp.concatenate(new_ssm, 0),
            "k": jnp.stack(new_k, 0),
            "v": jnp.stack(new_v, 0),
            "len": (cache["len"] + s) if mode == "decode" else jnp.int32(s),
        }
    return x, new_cache, aux


def embed_lookup(table: jax.Array, tokens: jax.Array, dtype) -> jax.Array:
    """One-hot-matmul embedding lookup. A plain gather's backward is a
    scatter-add that GSPMD materializes as a FULL unsharded (V, d) buffer
    per device; the one-hot contraction keeps both directions sharded."""
    v = table.shape[0]
    onehot = jax.nn.one_hot(tokens, v, dtype=table.dtype)
    return (onehot @ table).astype(dtype)


def _embed(cfg, params, tokens, img_embeds=None):
    x = embed_lookup(params["embed"]["tokens"], tokens, cfg.compute_dtype)
    if cfg.family == "vlm" and img_embeds is not None:
        x = jnp.concatenate([img_embeds.astype(cfg.compute_dtype), x], axis=1)
    return x


def _unembed(cfg, params, x):
    w = (params["embed"]["tokens"].T if cfg.tie_embeddings
         else params["unembed"])
    logits = x @ w.astype(x.dtype)
    if cfg.vocab_padded != cfg.vocab:
        # mask (not slice!) the padded columns: a slice of the vocab-sharded
        # dim would force an all-gather of the full logits tensor
        mask = jnp.arange(cfg.vocab_padded) < cfg.vocab
        logits = jnp.where(mask, logits, jnp.asarray(-1e30, logits.dtype))
    return logits


# ----------------------------- public entry ------------------------------ #

def cross_entropy(logits: jax.Array, labels: jax.Array,
                  vocab_padded: int) -> jax.Array:
    """Sharding-friendly CE: logsumexp + one-hot contraction (no gather over
    the vocab-sharded dim). One-hot stays in the logits dtype (bf16) to
    bound the transient; the contraction accumulates in f32."""
    logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    onehot = jax.nn.one_hot(labels, vocab_padded, dtype=logits.dtype)
    gold = jnp.einsum("bsv,bsv->bs", logits, onehot,
                      preferred_element_type=jnp.float32)
    return jnp.mean(logz - gold)


def loss_fn(cfg, params, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Next-token cross entropy; batch: tokens (B,S), labels (B,S),
    optional img_embeds (B,P,d)."""
    x = _embed(cfg, params, batch["tokens"], batch.get("img_embeds"))
    s = x.shape[1]
    positions = jnp.arange(s)
    x, _, aux = _run_layers(cfg, params, x, positions, None, "train")
    x = common.rmsnorm(x, params["final_norm"])
    if cfg.family == "vlm":
        x = x[:, -batch["tokens"].shape[1]:]       # loss on text tokens only
    logits = _unembed(cfg, params, x)
    labels = batch["labels"]
    ce = cross_entropy(logits, labels, cfg.vocab_padded)
    total = ce + AUX_LOSS_WEIGHT * aux
    return total, {"ce": ce, "aux": aux}


def prefill(cfg, params, batch, max_len: Optional[int] = None
            ) -> Tuple[jax.Array, Any]:
    """Process a prompt; returns (last-position logits, cache)."""
    tokens = batch["tokens"]
    x = _embed(cfg, params, tokens, batch.get("img_embeds"))
    s = x.shape[1]
    positions = jnp.arange(s)
    x, cache, _ = _run_layers(cfg, params, x, positions, None, "prefill")
    x = common.rmsnorm(x, params["final_norm"])
    logits = _unembed(cfg, params, x[:, -1:])
    if max_len is not None and max_len > s and cfg.family not in ("ssm",):
        pad = max_len - s
        for key in ("k", "v"):
            if key in cache:
                cache[key] = jnp.pad(
                    cache[key], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    return logits[:, 0], cache


def decode_step(cfg, params, cache, tokens: jax.Array
                ) -> Tuple[jax.Array, Any]:
    """One decode step. tokens: (B,) int32; cache from prefill/cache_specs.
    Returns (logits (B, V), new cache)."""
    x = _embed(cfg, params, tokens[:, None])
    positions = jnp.reshape(cache["len"], (1,))
    x, cache, _ = _run_layers(cfg, params, x, positions, cache, "decode")
    x = common.rmsnorm(x, params["final_norm"])
    logits = _unembed(cfg, params, x)
    return logits[:, 0], cache
