"""Shared transformer building blocks: norms, rotary, GQA attention (full,
kv-chunked flash-style, and cached decode), gated MLP."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .spec import ParamSpec


def rmsnorm_spec(d: int, dtype: str) -> dict:
    return {"scale": ParamSpec((d,), ("embed",), init="ones", dtype=dtype)}


def rmsnorm(x: jax.Array, p: dict, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return out.astype(x.dtype)


def rotary(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions broadcastable to (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-np.log(theta) * jnp.arange(half, dtype=jnp.float32)
                    / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs   # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                         # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ------------------------------ attention -------------------------------- #

def attn_specs(cfg, cross: bool = False) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim
    dt = cfg.param_dtype
    specs = {
        "wq": ParamSpec((d, h, hd), ("embed", "q_heads", "head_dim"),
                        dtype=dt),
        "wk": ParamSpec((d, kv, hd), ("embed", "kv_heads", "head_dim"),
                        dtype=dt),
        "wv": ParamSpec((d, kv, hd), ("embed", "kv_heads", "head_dim"),
                        dtype=dt),
        "wo": ParamSpec((h, hd, d), ("q_heads", "head_dim", "embed"),
                        dtype=dt),
    }
    if cfg.qkv_bias and not cross:
        specs["bq"] = ParamSpec((h, hd), ("q_heads", "head_dim"),
                                init="zeros", dtype=dt)
        specs["bk"] = ParamSpec((kv, hd), ("kv_heads", "head_dim"),
                                init="zeros", dtype=dt)
        specs["bv"] = ParamSpec((kv, hd), ("kv_heads", "head_dim"),
                                init="zeros", dtype=dt)
    return specs


def qkv_proj(p: dict, x: jax.Array, cfg) -> Tuple[jax.Array, jax.Array,
                                                  jax.Array]:
    ct = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(ct))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(ct))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(ct))
    if "bq" in p:
        q = q + p["bq"].astype(ct)
        k = k + p["bk"].astype(ct)
        v = v + p["bv"].astype(ct)
    return q, k, v


def _block_attn(q, k, v, mask, scale):
    """Unnormalized block attention: returns (acc, lse_max, denom)."""
    s = jnp.einsum("bsgkh,btkh->bkgst", q, k).astype(jnp.float32) * scale
    s = jnp.where(mask, s, -1e30)
    m = jnp.max(s, axis=-1)                          # (B,KV,G,S)
    e = jnp.exp(s - m[..., None])
    e = jnp.where(mask, e, 0.0)
    denom = jnp.sum(e, axis=-1)
    acc = jnp.einsum("bkgst,btkh->bkgsh", e.astype(v.dtype), v)
    return acc.astype(jnp.float32), m, denom


def gqa_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool, q_offset=0,
                  kv_len: Optional[jax.Array] = None,
                  chunk: int = 0) -> jax.Array:
    """Grouped-query attention.

    q: (B, S, H, hd); k, v: (B, T, KV, hd); H = KV * G.
    ``causal``: mask kv_idx > q_idx + q_offset.  ``kv_len``: valid cache
    length (decode).  ``chunk`` > 0 enables kv-chunked online-softmax
    (flash-style) when T > chunk — O(S * chunk) score memory.
    Returns (B, S, H, hd).
    """
    b, sq, h, hd = q.shape
    t = k.shape[1]
    kv = k.shape[2]
    g = h // kv
    scale = 1.0 / np.sqrt(hd)
    qg = q.reshape(b, sq, g, kv, hd)
    q_idx = jnp.asarray(q_offset) + jnp.arange(sq)

    def mask_for(t0, tc):
        kv_idx = t0 + jnp.arange(tc)
        m = jnp.ones((sq, tc), bool)
        if causal:
            m &= kv_idx[None, :] <= q_idx[:, None]
        if kv_len is not None:
            m &= kv_idx[None, :] < jnp.asarray(kv_len)
        return m[None, None, None]                   # (1,1,1,S,Tc)

    def finish(acc, denom):
        out = acc / jnp.maximum(denom, 1e-30)[..., None]
        # acc dims (B, KV, G, S, hd) -> (B, S, G, KV, hd) -> (B, S, H, hd),
        # inverting the q reshape (b, sq, g, kv, hd).
        return out.astype(q.dtype).transpose(0, 3, 2, 1, 4).reshape(
            b, sq, h, hd)

    if chunk <= 0 or t <= chunk:
        acc, _, denom = _block_attn(qg, k, v, mask_for(0, t), scale)
        return finish(acc, denom)

    n_chunks = -(-t // chunk)
    pad = n_chunks * chunk - t
    if pad:
        kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        eff_len = kv_len if kv_len is not None else t
    else:
        kp, vp = k, v
        eff_len = kv_len
    kc = kp.reshape(b, n_chunks, chunk, kv, hd)
    vc = vp.reshape(b, n_chunks, chunk, kv, hd)

    @jax.checkpoint
    def body(carry, idx_kc_vc):
        m_run, d_run, a_run = carry
        i, kb, vb = idx_kc_vc
        t0 = i * chunk
        kv_idx = t0 + jnp.arange(chunk)
        msk = jnp.ones((sq, chunk), bool)
        if causal:
            msk &= kv_idx[None, :] <= q_idx[:, None]
        if eff_len is not None:
            msk &= kv_idx[None, :] < jnp.asarray(eff_len)
        acc, m_blk, d_blk = _block_attn(qg, kb, vb, msk[None, None, None],
                                        scale)
        m_new = jnp.maximum(m_run, m_blk)
        s_run = jnp.exp(m_run - m_new)
        s_blk = jnp.exp(m_blk - m_new)
        d_new = d_run * s_run + d_blk * s_blk
        a_new = a_run * s_run[..., None] + acc * s_blk[..., None]
        return (m_new, d_new, a_new), None

    m0 = jnp.full((b, kv, g, sq), -jnp.inf, jnp.float32)
    d0 = jnp.zeros((b, kv, g, sq), jnp.float32)
    a0 = jnp.zeros((b, kv, g, sq, hd), jnp.float32)
    (m_f, d_f, a_f), _ = jax.lax.scan(
        body, (m0, d0, a0),
        (jnp.arange(n_chunks), kc.transpose(1, 0, 2, 3, 4),
         vc.transpose(1, 0, 2, 3, 4)))
    return finish(a_f, d_f)


def attn_out(p: dict, y: jax.Array) -> jax.Array:
    return jnp.einsum("bshk,hkd->bsd", y, p["wo"].astype(y.dtype))


# --------------------------------- mlp ----------------------------------- #

def mlp_specs(cfg, d_ff: Optional[int] = None, gated: bool = True) -> dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    dt = cfg.param_dtype
    specs = {
        "wi": ParamSpec((d, f), ("embed", "ffn"), dtype=dt),
        "wo": ParamSpec((f, d), ("ffn", "embed"), dtype=dt),
    }
    if gated:
        specs["wg"] = ParamSpec((d, f), ("embed", "ffn"), dtype=dt)
    return specs


def mlp(p: dict, x: jax.Array, act: str = "silu") -> jax.Array:
    ct = x.dtype
    h = x @ p["wi"].astype(ct)
    if "wg" in p:
        h = jax.nn.silu(h) * (x @ p["wg"].astype(ct))
    else:
        h = jax.nn.gelu(h) if act == "gelu" else jax.nn.silu(h)
    return h @ p["wo"].astype(ct)
