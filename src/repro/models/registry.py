"""Unified model interface: every assigned architecture exposes the same
five entry points, used by the trainer, server, dry-run, and smoke tests."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig
from . import encdec, transformer
from . import spec as spec_mod


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    param_specs: Dict[str, Any]

    # ---- parameters ----
    def abstract_params(self):
        return spec_mod.abstract(self.param_specs)

    def init(self, key: jax.Array):
        return spec_mod.initialize(self.param_specs, key)

    def n_params(self) -> int:
        return spec_mod.count_params(self.param_specs)

    def n_active_params(self) -> int:
        """Active parameters per token (MoE: top-k of the expert pool)."""
        cfg = self.cfg
        total = self.n_params()
        if cfg.family != "moe" or not cfg.moe_experts:
            return total
        import math
        specs = spec_mod.tree_paths(self.param_specs)
        expert = sum(
            math.prod(s.shape)
            for p, s in specs.items()
            if "/moe/w" in p)
        active = expert * cfg.moe_topk // cfg.moe_experts
        return total - expert + active

    # ---- compute ----
    def loss(self, params, batch):
        if self.cfg.family == "encdec":
            return encdec.loss_fn(self.cfg, params, batch)
        return transformer.loss_fn(self.cfg, params, batch)

    def prefill(self, params, batch, max_len: Optional[int] = None):
        if self.cfg.family == "encdec":
            return encdec.prefill(self.cfg, params, batch, max_len)
        return transformer.prefill(self.cfg, params, batch, max_len)

    def decode_step(self, params, cache, tokens):
        if self.cfg.family == "encdec":
            return encdec.decode_step(self.cfg, params, cache, tokens)
        return transformer.decode_step(self.cfg, params, cache, tokens)

    def cache_specs(self, batch: int, max_len: int):
        if self.cfg.family == "encdec":
            return encdec.cache_specs(self.cfg, batch, max_len)
        return transformer.cache_specs(self.cfg, batch, max_len)

    def abstract_cache(self, batch: int, max_len: int):
        return spec_mod.abstract(self.cache_specs(batch, max_len))

    def init_cache(self, batch: int, max_len: int):
        return spec_mod.map_specs(
            lambda p, s: jnp.zeros(s.shape, jnp.dtype(s.dtype)),
            self.cache_specs(batch, max_len))

    # ---- inputs ----
    def input_specs(self, shape: ShapeConfig,
                    batch_override: Optional[int] = None) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for every model input of one cell."""
        cfg = self.cfg
        b = batch_override or shape.global_batch
        s = shape.seq_len
        i32 = jnp.dtype("int32")
        f32 = jnp.dtype("float32")
        if shape.kind in ("train", "prefill"):
            out = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
            if shape.kind == "train":
                out["labels"] = jax.ShapeDtypeStruct((b, s), i32)
            if cfg.family == "encdec":
                out["frames"] = jax.ShapeDtypeStruct(
                    (b, cfg.enc_len, cfg.d_model), f32)
            if cfg.family == "vlm":
                out["img_embeds"] = jax.ShapeDtypeStruct(
                    (b, cfg.vlm_prefix, cfg.d_model), f32)
            return out
        # decode: one token with a KV/state cache of seq_len
        return {"tokens": jax.ShapeDtypeStruct((b,), i32)}

    def concrete_inputs(self, shape: ShapeConfig, key: jax.Array,
                        batch_override: Optional[int] = None):
        specs = self.input_specs(shape, batch_override)
        out = {}
        for name, s in specs.items():
            k = jax.random.fold_in(key, hash(name) % (2 ** 31))
            if jnp.issubdtype(s.dtype, jnp.integer):
                out[name] = jax.random.randint(k, s.shape, 0, self.cfg.vocab,
                                               dtype=s.dtype)
            else:
                out[name] = jax.random.normal(k, s.shape, s.dtype)
        return out


def build_model(cfg: ModelConfig) -> Model:
    if cfg.family == "encdec":
        specs = encdec.build_specs(cfg)
    else:
        specs = transformer.build_specs(cfg)
    return Model(cfg=cfg, param_specs=specs)
