"""Lightweight parameter-spec module system.

Models declare parameters as trees of ``ParamSpec`` (shape + dtype + logical
axes + initializer). From one spec tree we derive:
  * abstract params (``jax.ShapeDtypeStruct``) — used by the multi-pod
    dry-run so a 1T-parameter model never allocates;
  * concrete params (deterministic per-leaf fold_in init) — smoke tests,
    examples;
  * ``PartitionSpec`` trees via logical-axis rules (repro.parallel.sharding).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Tree = Any


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]          # logical axis names, len == ndim
    init: str = "normal"                     # normal | zeros | ones | scaled
    scale: float = 1.0                       # stddev multiplier / fan-in mode
    dtype: str = "float32"

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"axes {self.axes} do not match shape "
                             f"{self.shape}")


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def tree_paths(tree: Tree, prefix: str = "") -> Dict[str, ParamSpec]:
    out = {}
    if is_spec(tree):
        out[prefix] = tree
        return out
    for k in sorted(tree.keys()):
        out.update(tree_paths(tree[k], f"{prefix}/{k}" if prefix else k))
    return out


def map_specs(fn: Callable[[str, ParamSpec], Any], tree: Tree,
              prefix: str = "") -> Tree:
    if is_spec(tree):
        return fn(prefix, tree)
    return {k: map_specs(fn, v, f"{prefix}/{k}" if prefix else k)
            for k, v in tree.items()}


def abstract(tree: Tree) -> Tree:
    return map_specs(
        lambda p, s: jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype)), tree)


def _leaf_key(key: jax.Array, path: str) -> jax.Array:
    h = np.uint32(np.frombuffer(
        path.encode(), dtype=np.uint8).astype(np.uint64).sum() * 2654435761
        % (2 ** 31))
    return jax.random.fold_in(key, int(h))


def _fan_in(shape: Tuple[int, ...]) -> int:
    # convention: last axis is the output axis for >=2D weights
    if len(shape) <= 1:
        return 1
    return int(np.prod(shape[:-1]))


def initialize(tree: Tree, key: jax.Array) -> Tree:
    def init_leaf(path: str, s: ParamSpec):
        dt = jnp.dtype(s.dtype)
        if s.init == "zeros":
            return jnp.zeros(s.shape, dt)
        if s.init == "ones":
            return jnp.ones(s.shape, dt)
        k = _leaf_key(key, path)
        std = s.scale / np.sqrt(_fan_in(s.shape))
        return (jax.random.normal(k, s.shape, jnp.float32) * std).astype(dt)
    return map_specs(init_leaf, tree)


def partition_tree(tree: Tree, rules: Dict[str, Optional[Any]]) -> Tree:
    """logical axes -> jax.sharding.PartitionSpec via a rules dict."""
    from jax.sharding import PartitionSpec as P

    def leaf(path: str, s: ParamSpec):
        return P(*(rules.get(a) if a is not None else None for a in s.axes))
    return map_specs(leaf, tree)


def count_params(tree: Tree) -> int:
    return sum(int(np.prod(s.shape)) for s in tree_paths(tree).values())


def param_bytes(tree: Tree) -> int:
    return sum(int(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize
               for s in tree_paths(tree).values())


def stack_layers(tree: Tree, n_layers: int) -> Tree:
    """Prepend a scanned 'layers' axis to every leaf (for lax.scan stacks)."""
    return map_specs(
        lambda p, s: dataclasses.replace(
            s, shape=(n_layers,) + s.shape, axes=("layers",) + s.axes), tree)
