"""Mixture-of-Experts layer — GShard-style top-k routing with grouped
capacity dispatch (one-hot einsums; GSPMD-friendly for EP over the 'model'
axis). Used by kimi-k2 (384e top-8) and grok-1 (8e top-2).

Design notes:
  * tokens are split into ``moe_groups`` groups; the group axis stays a
    SEPARATE einsum dimension from batch (merging them into one reshaped
    dim gives GSPMD merged-dim shardings it can only reshard by full
    rematerialization — §Perf H2 measured 28 GiB/layer of gathers from
    exactly that). Capacity C = ceil(group_tokens * topk * cf / E).
  * experts axis shards over 'model' (EP) by default; grok-1 (8 experts <
    16 model shards) shards the expert FFN dim instead (moe_shard='ffn').
  * router in fp32, load-balance auxiliary loss returned to the trainer.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..parallel.ctx import constrain
from .spec import ParamSpec


def moe_specs(cfg) -> dict:
    d, e, f = cfg.d_model, cfg.moe_experts, cfg.moe_dff
    dt = cfg.param_dtype
    return {
        "router": ParamSpec((d, e), ("embed", "experts_r"), dtype="float32"),
        "wi": ParamSpec((e, d, f), ("experts", "embed", "expert_ffn"),
                        dtype=dt),
        "wg": ParamSpec((e, d, f), ("experts", "embed", "expert_ffn"),
                        dtype=dt),
        "wo": ParamSpec((e, f, d), ("experts", "expert_ffn", "embed"),
                        dtype=dt),
    }


def moe_layer(p: dict, x: jax.Array, cfg) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (y, aux_loss). Top-k softmax routing, capacity drop."""
    b, s, d = x.shape
    e, k = cfg.moe_experts, cfg.moe_topk
    ct = x.dtype

    g = min(cfg.moe_groups, s) or 1
    while s % g:
        g -= 1
    tokens = x.reshape(b, g, s // g, d)                  # (B, G, T, d)
    t = s // g
    cap = max(int(np.ceil(t * k * cfg.moe_cf / e)), 1)

    logits = (tokens.astype(jnp.float32)
              @ p["router"].astype(jnp.float32))         # (B, G, T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, k)                 # (B, G, T, k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch): E * sum_e f_e * p_e
    me = probs.mean(axis=(0, 1, 2))                      # (E,)
    ce = jnp.zeros((e,), jnp.float32).at[topi.reshape(-1)].add(
        jnp.ones((b * g * t * k,), jnp.float32)) / (b * g * t * k)
    aux = e * jnp.sum(me * ce)

    # position of each (token, choice) within its expert's capacity buffer
    onehot = jax.nn.one_hot(topi, e, dtype=jnp.float32)  # (B, G, T, k, E)
    flat = onehot.reshape(b, g, t * k, e)
    pos = (jnp.cumsum(flat, axis=2) - flat).reshape(b, g, t, k, e)
    pos = jnp.sum(pos * onehot, axis=-1)                 # (B, G, T, k)
    keep = pos < cap
    gate = topv * keep.astype(topv.dtype)

    pos_oh = jax.nn.one_hot(
        jnp.where(keep, pos, cap).astype(jnp.int32), cap,
        dtype=jnp.float32)                               # (B, G, T, k, C)
    disp = jnp.einsum("bgtke,bgtkc->bgtec", onehot * keep[..., None],
                      pos_oh)                            # (B, G, T, E, C)
    disp = constrain(disp, "act_batch", None, None, "experts", None)
    expert_in = jnp.einsum("bgtec,bgtd->bgecd", disp.astype(ct), tokens)
    expert_in = constrain(expert_in, "act_batch", None, "experts", None,
                          None)

    # expert FFN (E sharded over 'model' [EP] or F sharded [TP], per rules)
    h = jnp.einsum("bgecd,edf->bgecf", expert_in, p["wi"].astype(ct))
    hg = jnp.einsum("bgecd,edf->bgecf", expert_in, p["wg"].astype(ct))
    h = constrain(jax.nn.silu(h) * hg, "act_batch", None, "experts", None,
                  "expert_ffn")
    expert_out = jnp.einsum("bgecf,efd->bgecd", h, p["wo"].astype(ct))
    expert_out = constrain(expert_out, "act_batch", None, "experts", None,
                           None)

    cw = jnp.einsum("bgtke,bgtkc,bgtk->bgtec", onehot * keep[..., None],
                    pos_oh, gate)                        # combine weights
    cw = constrain(cw, "act_batch", None, None, "experts", None)
    y = jnp.einsum("bgtec,bgecd->bgtd", cw.astype(ct), expert_out)
    return y.reshape(b, s, d), aux
