"""Whisper-small encoder-decoder backbone (paper-assigned [audio] arch).

The conv/mel frontend is a STUB per the brief: ``input_specs()`` supplies
precomputed frame embeddings (B, enc_len, d_model). Backbone deviations from
upstream Whisper (documented): rotary positions instead of learned/sinusoidal
embeddings (keeps parameter shapes independent of the assigned decode
lengths), RMSNorm, gated-silu MLP — i.e. the shared block library. Decode
uses a self-attention KV cache plus cross-attention K/V computed once.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from . import common
from ..parallel.ctx import constrain
from .spec import ParamSpec, stack_layers
from .transformer import scan_or_loop


def _enc_layer_specs(cfg) -> dict:
    return {
        "ln1": common.rmsnorm_spec(cfg.d_model, cfg.param_dtype),
        "attn": common.attn_specs(cfg),
        "ln2": common.rmsnorm_spec(cfg.d_model, cfg.param_dtype),
        "mlp": common.mlp_specs(cfg),
    }


def _dec_layer_specs(cfg) -> dict:
    return {
        "ln1": common.rmsnorm_spec(cfg.d_model, cfg.param_dtype),
        "attn": common.attn_specs(cfg),
        "lnx": common.rmsnorm_spec(cfg.d_model, cfg.param_dtype),
        "xattn": common.attn_specs(cfg, cross=True),
        "ln2": common.rmsnorm_spec(cfg.d_model, cfg.param_dtype),
        "mlp": common.mlp_specs(cfg),
    }


def build_specs(cfg) -> dict:
    return {
        "embed": {"tokens": ParamSpec((cfg.vocab_padded, cfg.d_model),
                                      ("vocab", "embed"),
                                      dtype=cfg.param_dtype)},
        "enc_layers": stack_layers(_enc_layer_specs(cfg), cfg.enc_layers),
        "enc_norm": common.rmsnorm_spec(cfg.d_model, cfg.param_dtype),
        "dec_layers": stack_layers(_dec_layer_specs(cfg), cfg.n_layers),
        "final_norm": common.rmsnorm_spec(cfg.d_model, cfg.param_dtype),
        "unembed": ParamSpec((cfg.d_model, cfg.vocab_padded),
                             ("embed", "vocab"), dtype=cfg.param_dtype),
    }


def cache_specs(cfg, batch: int, max_len: int) -> dict:
    ct = cfg.compute_dtype
    kv, hd = cfg.n_kv, cfg.head_dim
    return {
        "k": ParamSpec((cfg.n_layers, batch, max_len, kv, hd),
                       ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
                       dtype=ct),
        "v": ParamSpec((cfg.n_layers, batch, max_len, kv, hd),
                       ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
                       dtype=ct),
        "xk": ParamSpec((cfg.n_layers, batch, cfg.enc_len, kv, hd),
                        ("layers", "batch", None, "kv_heads", "head_dim"),
                        dtype=ct),
        "xv": ParamSpec((cfg.n_layers, batch, cfg.enc_len, kv, hd),
                        ("layers", "batch", None, "kv_heads", "head_dim"),
                        dtype=ct),
        "len": ParamSpec((), (), init="zeros", dtype="int32"),
    }


def encode(cfg, params, frames: jax.Array) -> jax.Array:
    """frames: (B, enc_len, d_model) stub embeddings -> encoder output."""
    x = frames.astype(cfg.compute_dtype)
    positions = jnp.arange(x.shape[1])

    def body(carry, lp):
        h = constrain(carry, "act_batch", "act_seq", None)
        a = common.rmsnorm(h, lp["ln1"])
        q, k, v = common.qkv_proj(lp["attn"], a, cfg)
        q = common.rotary(q, positions, cfg.rope_theta)
        k = common.rotary(k, positions, cfg.rope_theta)
        y = common.gqa_attention(q, k, v, causal=False, chunk=0)
        h = h + common.attn_out(lp["attn"], y)
        m = common.rmsnorm(h, lp["ln2"])
        h = h + common.mlp(lp["mlp"], m, act="gelu")
        return h, None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = scan_or_loop(body, x, params["enc_layers"], cfg.enc_layers,
                        cfg.scan_layers)
    return common.rmsnorm(x, params["enc_norm"])


def _cross_kv(cfg, lp, enc_out):
    ct = enc_out.dtype
    k = jnp.einsum("btd,dhk->bthk", enc_out, lp["xattn"]["wk"].astype(ct))
    v = jnp.einsum("btd,dhk->bthk", enc_out, lp["xattn"]["wv"].astype(ct))
    return k, v


def _decoder(cfg, params, tokens, positions, enc_out=None, cache=None,
             mode: str = "train"):
    from .transformer import embed_lookup
    x = embed_lookup(params["embed"]["tokens"], tokens, cfg.compute_dtype)

    def body(carry, xs):
        h = constrain(carry, "act_batch", "act_seq", None)
        lp, cs = xs
        # self attention (causal / cached)
        a = common.rmsnorm(h, lp["ln1"])
        q, k, v = common.qkv_proj(lp["attn"], a, cfg)
        q = common.rotary(q, positions, cfg.rope_theta)
        k = common.rotary(k, positions, cfg.rope_theta)
        if mode == "decode":
            kc = jax.lax.dynamic_update_slice(
                cs["k"], k.astype(cs["k"].dtype), (0, cs["len"], 0, 0))
            vc = jax.lax.dynamic_update_slice(
                cs["v"], v.astype(cs["v"].dtype), (0, cs["len"], 0, 0))
            y = common.gqa_attention(q, kc, vc, causal=False,
                                     q_offset=cs["len"],
                                     kv_len=cs["len"] + 1, chunk=0)
            new_cs = {"k": kc, "v": vc}
        else:
            y = common.gqa_attention(q, k, v, causal=True,
                                     chunk=cfg.attn_chunk
                                     if q.shape[1] > cfg.attn_chunk else 0)
            new_cs = {"k": k, "v": v} if mode == "prefill" else None
        h = h + common.attn_out(lp["attn"], y)
        # cross attention
        a = common.rmsnorm(h, lp["lnx"])
        qx = jnp.einsum("bsd,dhk->bshk", a,
                        lp["xattn"]["wq"].astype(a.dtype))
        if mode == "decode":
            xk, xv = cs["xk"], cs["xv"]
        else:
            xk, xv = _cross_kv(cfg, lp, enc_out)
        yx = common.gqa_attention(qx, xk, xv, causal=False, chunk=0)
        h = h + jnp.einsum("bshk,hkd->bsd", yx,
                           lp["xattn"]["wo"].astype(h.dtype))
        if new_cs is not None and mode == "prefill":
            new_cs.update({"xk": xk, "xv": xv})
        elif new_cs is not None:
            new_cs.update({"xk": cs["xk"], "xv": cs["xv"]})
        # mlp
        m = common.rmsnorm(h, lp["ln2"])
        h = h + common.mlp(lp["mlp"], m, act="gelu")
        return h, new_cs

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)

    if mode == "decode":
        xs_cache = {"k": cache["k"], "v": cache["v"],
                    "xk": cache["xk"], "xv": cache["xv"],
                    "len": jnp.broadcast_to(cache["len"], (cfg.n_layers,))}
    else:
        xs_cache = None
    x, new_cs = scan_or_loop(body, x, (params["dec_layers"], xs_cache),
                             cfg.n_layers, cfg.scan_layers)
    x = common.rmsnorm(x, params["final_norm"])
    logits = x @ params["unembed"].astype(x.dtype)
    if cfg.vocab_padded != cfg.vocab:
        mask = jnp.arange(cfg.vocab_padded) < cfg.vocab
        logits = jnp.where(mask, logits, jnp.asarray(-1e30, logits.dtype))
    return logits, new_cs


def loss_fn(cfg, params, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    enc_out = encode(cfg, params, batch["frames"])
    s = batch["tokens"].shape[1]
    logits, _ = _decoder(cfg, params, batch["tokens"], jnp.arange(s),
                         enc_out=enc_out, mode="train")
    from .transformer import cross_entropy
    ce = cross_entropy(logits, batch["labels"], cfg.vocab_padded)
    return ce, {"ce": ce, "aux": jnp.float32(0.0)}


def prefill(cfg, params, batch, max_len=None) -> Tuple[jax.Array, Any]:
    enc_out = encode(cfg, params, batch["frames"])
    s = batch["tokens"].shape[1]
    logits, cs = _decoder(cfg, params, batch["tokens"], jnp.arange(s),
                          enc_out=enc_out, mode="prefill")
    cache = {"k": cs["k"], "v": cs["v"], "xk": cs["xk"], "xv": cs["xv"],
             "len": jnp.int32(s)}
    if max_len is not None and max_len > s:
        pad = max_len - s
        for key in ("k", "v"):
            cache[key] = jnp.pad(
                cache[key], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    return logits[:, -1], cache


def decode_step(cfg, params, cache, tokens: jax.Array
                ) -> Tuple[jax.Array, Any]:
    positions = jnp.reshape(cache["len"], (1,))
    logits, new_cs = _decoder(cfg, params, tokens[:, None], positions,
                              cache=cache, mode="decode")
    new_cache = {"k": new_cs["k"], "v": new_cs["v"],
                 "xk": new_cs["xk"], "xv": new_cs["xv"],
                 "len": cache["len"] + 1}
    return logits[:, 0], new_cache
