# Launchers: mesh.py, dryrun.py, train.py, serve.py, escg_run.py.
# NOTE: dryrun must be imported/run as __main__ only (it sets XLA_FLAGS).
