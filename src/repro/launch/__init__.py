# ESCG entry points: escg_run.py (CLI driver/matrix), serve.py
# (escg_serve scenario server, DESIGN.md §12).
# LM-scaffold appendix (DESIGN.md §9, quarantined): mesh.py, dryrun.py,
# train.py — not ESCG entry points.
# NOTE: dryrun must be imported/run as __main__ only (it sets XLA_FLAGS).
