"""``escg_serve`` — the ESCG scenario-serving entry point (DESIGN.md §12).

Replay a JSONL request trace (or a synthetic smoke mix) through an
in-process :class:`~repro.serve.server.ScenarioServer` and emit the
throughput/latency report, or expose the same server over the stdlib
HTTP adapter with ``--http``.

Examples::

    escg_serve --synthetic 10 --waves 2 --report report.json
    escg_serve --trace examples/traces/smoke.jsonl --check
    escg_serve --http --port 8787        # POST /submit, /drain, ...

(The LM-framework scaffold that previously lived here — a granite
prefill/decode driver — was retired in favour of this; see DESIGN.md §9
for what remains quarantined of that scaffold.)
"""
from __future__ import annotations

import argparse
import json
import sys


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="escg_serve",
        description="ESCG scenario server: replay request traces against "
                    "the continuously-batched in-process server, or "
                    "serve HTTP (DESIGN.md §12)")
    src = ap.add_mutually_exclusive_group()
    src.add_argument("--trace", type=str, default=None,
                     help="JSONL trace of SimRequest wire objects")
    src.add_argument("--synthetic", type=int, default=None, metavar="N",
                     help="generate an N-request synthetic smoke trace")
    ap.add_argument("--seed", type=int, default=0,
                    help="seed for --synthetic (default 0)")
    ap.add_argument("--waves", type=int, default=2,
                    help="trace replay waves; later waves exercise the "
                         "compiled-engine cache-hit path (default 2)")
    ap.add_argument("--maxBatchTrials", type=int, default=64,
                    help="trials packed per device batch (default 64)")
    ap.add_argument("--cacheEntries", type=int, default=8,
                    help="LRU compiled-engine cache entries (default 8)")
    ap.add_argument("--maxResponses", type=int, default=4096,
                    help="answered responses retained before oldest-first "
                         "eviction; clients can POST /ack to release "
                         "eagerly (default 4096)")
    ap.add_argument("--report", type=str, default=None,
                    help="write the replay report JSON here")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero unless the report passes the "
                         "acceptance checks (zero dropped, zero errors, "
                         ">=1 cache hit)")
    ap.add_argument("--emitTrace", type=str, default=None, metavar="PATH",
                    help="write the (synthetic) trace to PATH and exit")
    ap.add_argument("--http", action="store_true",
                    help="serve the HTTP adapter instead of replaying")
    ap.add_argument("--host", type=str, default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8787)
    return ap


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    from repro.serve import loadgen
    from repro.serve.server import ScenarioServer

    if args.emitTrace is not None:
        reqs = loadgen.synthetic_trace(args.synthetic or 10, args.seed)
        loadgen.write_trace(args.emitTrace, reqs)
        print(f"wrote {len(reqs)} requests to {args.emitTrace}")
        return 0

    server = ScenarioServer(max_batch_trials=args.maxBatchTrials,
                            cache_entries=args.cacheEntries,
                            max_responses=args.maxResponses)

    if args.http:
        from repro.serve.httpd import serve_http
        print(f"escg_serve: HTTP on {args.host}:{args.port} "
              "(POST /submit, /drain; GET /response, /accounting)")
        serve_http(server, args.host, args.port)
        return 0

    if args.trace is not None:
        reqs = loadgen.read_trace(args.trace)
    else:
        reqs = loadgen.synthetic_trace(args.synthetic or 10, args.seed)
    report = loadgen.replay(server, reqs, waves=args.waves)
    out = json.dumps(report, indent=2, default=str)
    if args.report:
        with open(args.report, "w", encoding="utf-8") as f:
            f.write(out + "\n")
    print(f"escg_serve: {report['n_requests']} requests "
          f"({report['waves']} waves) in {report['wall_s']:.2f}s — "
          f"{report['requests_per_s']:.2f} req/s, "
          f"{report['updates_per_s'] / 1e6:.3f} Mupd/s; cache "
          f"{report['cache']['hits']}H/{report['cache']['misses']}M, "
          f"dropped={report['dropped']}")
    if not args.report:
        print(out)
    if args.check:
        problems = loadgen.check_report(report)
        for p in problems:
            print(f"escg_serve: CHECK FAILED: {p}", file=sys.stderr)
        return 1 if problems else 0
    return 0


if __name__ == "__main__":
    sys.exit(main())
