"""Batched serving driver: prefill a batch of prompts, decode N tokens
(deliverable b; greedy decoding on synthetic prompts)."""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import get_arch
from ..configs.base import ShapeConfig
from ..data.synthetic import batch_for_model
from ..models.registry import build_model


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default="granite-3-8b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt_len", type=int, default=64)
    ap.add_argument("--gen_len", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    max_len = args.prompt_len + args.gen_len

    shape = ShapeConfig("serve", args.prompt_len, args.batch, "prefill")
    batch = batch_for_model(model, shape, 0, args.seed)

    prefill = jax.jit(lambda p, b: model.prefill(p, b, max_len=max_len))
    decode = jax.jit(model.decode_step, donate_argnums=(1,))

    t0 = time.time()
    logits, cache = prefill(params, batch)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    t_prefill = time.time() - t0

    out = [tok]
    t0 = time.time()
    for _ in range(args.gen_len - 1):
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    seqs = jnp.stack(out, axis=1)
    print(f"[serve] arch={cfg.name} batch={args.batch} "
          f"prompt={args.prompt_len} gen={args.gen_len}")
    print(f"[serve] prefill {t_prefill*1e3:.1f} ms; decode "
          f"{t_decode*1e3:.1f} ms total, "
          f"{args.batch*(args.gen_len-1)/max(t_decode,1e-9):.1f} tok/s")
    print(f"[serve] sample continuation tokens: {seqs[0][:16].tolist()}")


if __name__ == "__main__":
    main()
