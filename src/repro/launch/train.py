"""[LM-scaffold appendix — NOT an ESCG entry point; DESIGN.md §9.]

End-to-end LM training driver retained from the quarantined LM-framework
scaffold (synthetic pipeline, AdamW/Adafactor, checkpoint/restart fault
tolerance, optional int8-EF gradient compression). The ESCG entry points
are ``escg_run`` (repro.launch.escg_run) and ``escg_serve``
(repro.launch.serve); nothing in the ESCG reproduction imports this
module.

Example (trains a ~100M-param granite-family model):
  PYTHONPATH=src python -m repro.launch.train --arch granite-3-8b \
      --reduced --d_model 512 --layers 12 --steps 300 --batch 8 --seq 256
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import get_arch
from ..configs.base import ShapeConfig
from ..data.synthetic import batch_for_model
from ..models.registry import build_model
from ..optim import cosine_schedule
from ..runtime import train_lib
from ..runtime.checkpoint import CheckpointManager
from ..runtime.fault import (FaultTolerantLoop, Heartbeat,
                             StragglerMonitor)


def main() -> None:
    ap = argparse.ArgumentParser(
        description="LM-scaffold appendix driver (DESIGN.md §9) — not an "
                    "ESCG entry point; use escg_run / escg_serve for the "
                    "reproduction")
    ap.add_argument("--arch", type=str, default="granite-3-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--d_model", type=int, default=0)
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--heads", type=int, default=0)
    ap.add_argument("--vocab", type=int, default=0)
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt_dir", type=str, default="ckpt_train")
    ap.add_argument("--ckpt_every", type=int, default=100)
    ap.add_argument("--compress", action="store_true",
                    help="int8 error-feedback gradient compression")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log_every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    kw = {}
    if args.d_model:
        kw.update(d_model=args.d_model,
                  head_dim=args.d_model // max(1, (args.heads or 8)))
    if args.layers:
        kw["n_layers"] = args.layers
    if args.heads:
        kw.update(n_heads=args.heads, n_kv=max(1, args.heads // 2))
    if args.vocab:
        kw["vocab"] = args.vocab
    if kw:
        cfg = cfg.replace(**kw)
    model = build_model(cfg)
    print(f"[train] arch={cfg.name} params={model.n_params():,} "
          f"(active {model.n_active_params():,})")

    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    schedule = cosine_schedule(args.lr, warmup=min(100, args.steps // 10),
                               total=args.steps)

    step_fn = jax.jit(train_lib.make_train_step(
        model, schedule=schedule, compress=args.compress),
        donate_argnums=(0,))

    ckpt = CheckpointManager(args.ckpt_dir, keep=3)
    state = None
    start = 0
    if args.resume and ckpt.latest_step() is not None:
        start, state = ckpt.restore()
        print(f"[train] resumed from step {start}")
    if state is None:
        state = train_lib.init_state(model, jax.random.PRNGKey(args.seed),
                                     compress=args.compress)

    losses = []

    def on_metrics(step, m):
        losses.append(float(m["loss"]))
        if step % args.log_every == 0:
            print(f"[train] step {step:5d} loss {float(m['loss']):.4f} "
                  f"gnorm {float(m['grad_norm']):.3f} "
                  f"lr {float(m['lr']):.2e}", flush=True)

    import os
    loop = FaultTolerantLoop(
        step_fn, ckpt, ckpt_every=args.ckpt_every,
        straggler=StragglerMonitor(),
        heartbeat=Heartbeat(os.path.join(args.ckpt_dir, "heartbeat"),
                            interval_s=10.0))
    t0 = time.time()
    state, end = loop.run(
        state, lambda s: batch_for_model(model, shape, s, args.seed),
        args.steps, start_step=start, on_metrics=on_metrics)
    dt = time.time() - t0
    print(f"[train] done: steps {start}->{end} in {dt:.1f}s "
          f"({(end - start) / max(dt, 1e-9):.2f} steps/s); "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
