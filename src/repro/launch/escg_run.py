"""ESCG simulation driver — CLI-parity with the paper (Tables 3.1/3.2).

This is the production entry point for the paper's own workload: the
end-to-end driver of this framework's kind (simulation). Supports all four
engines, --save/--resume state round-trips, dominance CSV import, periodic
snapshots and density export.

Examples:
  python -m repro.launch.escg_run --length 200 --height 200 --mcs 2000 \
      --engine batched --save true --outDir out/rps
  python -m repro.launch.escg_run --dominance dominance.csv --resume true \
      --outDir out/rps            # continue a saved run
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import numpy as np

from ..core import dominance as dom_mod
from ..core import engines
from ..core import io as io_mod
from ..core.params import EscgParams, add_cli_args, params_from_args
from ..core.simulation import simulate


def print_engine_matrix() -> None:
    """Registry-driven engine table (also mirrored in README.md)."""
    print(f"{'engine':<13} {'boundaries':<11} {'tiled':<6} {'devices':<8} "
          f"paper ref")
    for spec in engines.engine_specs():
        c = spec.caps
        print(f"{spec.name:<13} {'flux-only' if c.flux_only else 'any':<11} "
              f"{'yes' if c.tiled else 'no':<6} "
              f"{'multi' if c.multi_device else 'single':<8} {c.paper}")
        print(f"{'':13} {spec.caps.description}")


def main() -> None:
    ap = argparse.ArgumentParser(description="ESCG simulator (paper CLI)")
    add_cli_args(ap)
    ap.add_argument("--snapshotEvery", dest="snapshot_every", type=int,
                    default=0, help="save lattice snapshot every N MCS")
    ap.add_argument("--listEngines", dest="list_engines",
                    action="store_true",
                    help="print the registered engine matrix and exit")
    args = ap.parse_args()

    if args.list_engines:
        print_engine_matrix()
        return

    grid0 = None
    key = None
    start_mcs = 0
    if args.resume:
        params, grid0, start_mcs, dom, key_arr = io_mod.load_state(
            args.out_dir)
        params = params.replace(resume=True)
        key = (jax.numpy.asarray(key_arr) if key_arr is not None
               else jax.random.fold_in(jax.random.PRNGKey(params.seed),
                                       start_mcs))
        # allow the CLI to extend the run beyond the saved target
        params = params.replace(mcs=max(params.mcs, args.mcs))
        print(f"[escg] resumed {args.out_dir} at MCS {start_mcs}")
    else:
        params = params_from_args(args)
        if args.dominance:
            with open(args.dominance) as f:
                dom = dom_mod.from_csv(f.read())
            params = params.replace(species=dom.shape[0] - 1)
        else:
            # default circulant: RPS for 3, C(S,{1,2}) for 5+, C(S,{1}) else
            offs = (1, 2) if params.species >= 5 else (1,)
            dom = dom_mod.circulant(params.species, offs)

    params = params.replace(mcs=params.mcs - start_mcs).validate()

    hooks = []
    if args.snapshot_every:
        def snap_hook(mcs_done, grid, cnts):
            if mcs_done % args.snapshot_every == 0:
                io_mod.save_snapshot(params.out_dir, np.asarray(grid),
                                     start_mcs + mcs_done)
        hooks.append(snap_hook)

    t0 = time.time()
    res = simulate(params, dom, grid0=grid0, key=key, hooks=hooks)
    dt = time.time() - t0

    n = params.n_cells
    total_mcs = start_mcs + res.mcs_completed
    print(f"[escg] {params.height}x{params.length} species={params.species}"
          f" engine={params.engine}: {res.mcs_completed} MCS in {dt:.2f}s"
          f" ({res.mcs_completed * n / max(dt, 1e-9):.3g} updates/s)")
    if res.stasis_mcs >= 0:
        print(f"[escg] stasis (monoculture/dead) at MCS "
              f"{start_mcs + res.stasis_mcs}")
    print("[escg] final densities:", np.round(res.densities[-1], 4))

    if params.save:
        os.makedirs(params.out_dir, exist_ok=True)
        io_mod.save_state(params.out_dir, params.replace(mcs=args.mcs),
                          res.grid, total_mcs, np.asarray(dom))
        io_mod.export_densities_csv(
            os.path.join(params.out_dir, "densities.csv"), res.densities)
        print(f"[escg] state + densities saved to {params.out_dir}")


if __name__ == "__main__":
    main()
