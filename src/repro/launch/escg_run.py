"""ESCG simulation driver — CLI-parity with the paper (Tables 3.1/3.2).

This is the production entry point for the paper's own workload: the
end-to-end driver of this framework's kind (simulation). Supports every
registered engine, --save/--resume state round-trips, dominance CSV import,
periodic snapshots and density export.

Beyond the paper's CLI it exposes the two scaling axes and their
composition (DESIGN.md §4-§6):

* ``--engine sharded [--shardGrid R C] [--localKernel pallas|fused]`` —
  one big lattice decomposed across devices (grid axis); ``--localKernel``
  selects the in-region tile sweep implementation: ``jnp``/``pallas`` are
  bit-identical to each other, ``fused`` derives proposals in-kernel from
  Philox counters keyed by global tile identity (zero proposal HBM
  traffic, bit-identical to ``--engine pallas_fused``).
* ``--trials N [--trialDevices D]`` — N IID replicate lattices, vmapped
  and sharded across devices over the trial axis (pod axis). Prints
  streamed survival / stasis statistics; with ``--save true`` the full
  ``TrialResult`` JSON lands in ``<outDir>/trials.json``. Results are
  bit-identical for any ``--trialDevices`` (per-trial fold-in PRNG keys).
* ``--trials N --engine sharded_pod --meshShape P,R,C`` — BOTH axes at
  once on a composed ('pod','rows','cols') mesh: trials shard over the
  pod axis while every trial's lattice is domain-decomposed over
  (rows, cols) with halo exchange. Bit-identical to the single-device
  run for any factorization.

Examples:
  python -m repro.launch.escg_run --length 200 --height 200 --mcs 2000 \
      --engine batched --save true --outDir out/rps
  python -m repro.launch.escg_run --dominance dominance.csv --resume true \
      --outDir out/rps            # continue a saved run
  python -m repro.launch.escg_run --length 100 --height 100 --species 8 \
      --trials 64 --mcs 10000     # Park-style massed IID replication
  python -m repro.launch.escg_run --length 800 --height 800 --species 8 \
      --trials 16 --mcs 10000 --engine sharded_pod --meshShape 4,2,2 \
      --tile 8 32                 # massed replication of LARGE lattices
  python -m repro.launch.escg_run --length 800 --height 800 --species 8 \
      --trials 16 --mcs 10000 --engine sharded_pod --meshShape 4,2,2 \
      --tile 8 32 --localKernel fused   # same, zero proposal HBM traffic
  python -m repro.launch.escg_run --listEngines --markdown   # engine matrix
"""
from __future__ import annotations

import argparse
import os
import re
import time
from typing import Optional

import jax
import numpy as np

from ..core import dominance as dom_mod
from ..core import engines
from ..core import io as io_mod
from ..core.params import EscgParams, add_cli_args, params_from_args
from ..core.simulation import simulate
from ..core.trials import run_trials

# ------------------------- engine matrix (docs) --------------------------- #

_MATRIX_HEAD = ("engine", "boundaries", "tile", "devices", "trial axis",
                "local kernels", "reproduces")
_MATRIX_BEGIN = ("<!-- engine-matrix:begin (generated: escg_run "
                 "--listEngines --markdown; CI-checked) -->")
_MATRIX_END = "<!-- engine-matrix:end -->"


def engine_matrix_rows():
    """One row per registered engine, derived purely from EngineCaps."""
    rows = []
    for spec in engines.engine_specs():
        c = spec.caps
        tile = ("must divide device blocks" if c.multi_device
                else "must divide lattice") if c.tiled else "—"
        rows.append((f"`{spec.name}`",
                     "flux only" if c.flux_only else "flux or reflect",
                     tile,
                     "multi" if c.multi_device else "single",
                     c.trial_axis,
                     ", ".join(f"`{k}`" for k in c.local_kernels) or "—",
                     f"{c.paper} — {c.description}"))
    return rows


def engine_matrix_markdown() -> str:
    """The README engine matrix, generated from the live registry."""
    lines = ["| " + " | ".join(_MATRIX_HEAD) + " |",
             "|" + "---|" * len(_MATRIX_HEAD)]
    for row in engine_matrix_rows():
        lines.append("| " + " | ".join(row) + " |")
    return "\n".join(lines)


def readme_matrix_drift(readme_path: str) -> Optional[str]:
    """None when the README block between the engine-matrix markers equals
    the registry-generated table; else a human-readable drift message.
    Used by ``--listEngines --check`` (CI) and tests/test_docs.py."""
    with open(readme_path) as f:
        text = f.read()
    m = re.search(re.escape(_MATRIX_BEGIN) + r"\n(.*?)\n"
                  + re.escape(_MATRIX_END), text, re.S)
    if not m:
        return f"{readme_path}: engine-matrix markers not found"
    want = engine_matrix_markdown().strip()
    got = m.group(1).strip()
    if got != want:
        return (f"{readme_path}: engine matrix drifted from the registry.\n"
                f"Regenerate with:\n  PYTHONPATH=src python -m "
                f"repro.launch.escg_run --listEngines --markdown\n"
                f"--- README ---\n{got}\n--- registry ---\n{want}")
    return None


def print_engine_matrix() -> None:
    """Registry-driven engine table (plain-text variant)."""
    print(f"{'engine':<13} {'boundaries':<11} {'tiled':<6} {'devices':<8} "
          f"{'trial axis':<17} paper ref")
    for spec in engines.engine_specs():
        c = spec.caps
        print(f"{spec.name:<13} {'flux-only' if c.flux_only else 'any':<11} "
              f"{'yes' if c.tiled else 'no':<6} "
              f"{'multi' if c.multi_device else 'single':<8} "
              f"{c.trial_axis:<17} {c.paper}")
        print(f"{'':13} {spec.caps.description}")


# ------------------------------ trial mode -------------------------------- #

def run_trial_batch(params: EscgParams, dom: np.ndarray, n_trials: int,
                    trial_devices: Optional[int]) -> None:
    """--trials N: massed IID replication through the pod-axis driver."""
    def progress(mcs_done, alive_counts):
        in_stasis = int((alive_counts <= 1).sum())
        print(f"[escg]   chunk -> MCS {mcs_done}: {in_stasis}/{n_trials} "
              f"trials in stasis", flush=True)

    t0 = time.time()
    res = run_trials(params, dom, n_trials, trial_devices=trial_devices,
                     hooks=[progress])
    dt = time.time() - t0

    upd = res.mcs_completed * params.n_cells * n_trials
    print(f"[escg] {n_trials} trials x {params.height}x{params.length} "
          f"species={params.species} engine={params.engine} on "
          f"{res.n_devices} device(s): {res.mcs_completed} MCS in {dt:.2f}s "
          f"({upd / max(dt, 1e-9):.3g} updates/s aggregate)")
    print(f"[escg] survival probabilities: "
          f"{np.round(res.survival_probabilities(), 4)}")
    print(f"[escg] survivors histogram:    "
          f"{np.round(res.survivors_hist(), 4)}")
    n_stasis = int((res.stasis_mcs >= 0).sum())
    if n_stasis:
        reached = res.stasis_mcs[res.stasis_mcs >= 0]
        print(f"[escg] stasis reached in {n_stasis}/{n_trials} trials "
              f"(median MCS {int(np.median(reached))})")
    if params.save:
        os.makedirs(params.out_dir, exist_ok=True)
        path = os.path.join(params.out_dir, "trials.json")
        with open(path, "w") as f:
            f.write(res.to_json())
        print(f"[escg] TrialResult saved to {path}")


# --------------------------------- main ----------------------------------- #

def main() -> None:
    ap = argparse.ArgumentParser(description="ESCG simulator (paper CLI)")
    add_cli_args(ap)
    ap.add_argument("--snapshotEvery", dest="snapshot_every", type=int,
                    default=0, help="save lattice snapshot every N MCS")
    ap.add_argument("--trials", type=int, default=0,
                    help="run N IID trials (vmapped, sharded across devices "
                         "over the trial axis) instead of one simulation; "
                         "prints survival/stasis statistics")
    ap.add_argument("--trialDevices", dest="trial_devices", type=int,
                    default=None,
                    help="pod width for --trials: number of local devices "
                         "to shard the trial axis across (default: all; "
                         "results are bit-identical for any value)")
    ap.add_argument("--listEngines", dest="list_engines",
                    action="store_true",
                    help="print the registered engine matrix and exit")
    ap.add_argument("--markdown", action="store_true",
                    help="with --listEngines: print the matrix as the "
                         "markdown table embedded in README.md")
    ap.add_argument("--check", dest="check_readme", metavar="README",
                    default=None,
                    help="with --listEngines: exit non-zero if README's "
                         "engine matrix drifted from the registry (CI)")
    args = ap.parse_args()

    if args.list_engines:
        if args.check_readme:
            drift = readme_matrix_drift(args.check_readme)
            if drift:
                raise SystemExit(drift)
            print(f"[escg] {args.check_readme} engine matrix matches the "
                  "registry")
        elif args.markdown:
            print(engine_matrix_markdown())
        else:
            print_engine_matrix()
        return

    grid0 = None
    key = None
    start_mcs = 0
    if args.resume:
        if args.trials:
            raise SystemExit("--trials and --resume are mutually exclusive "
                             "(trial batches keep no host-side state)")
        params, grid0, start_mcs, dom, key_arr = io_mod.load_state(
            args.out_dir)
        params = params.replace(resume=True)
        key = (jax.numpy.asarray(key_arr) if key_arr is not None
               else jax.random.fold_in(jax.random.PRNGKey(params.seed),
                                       start_mcs))
        # allow the CLI to extend the run beyond the saved target
        params = params.replace(mcs=max(params.mcs, args.mcs))
        print(f"[escg] resumed {args.out_dir} at MCS {start_mcs}")
    else:
        params = params_from_args(args)
        if args.dominance:
            with open(args.dominance) as f:
                dom = dom_mod.from_csv(f.read())
            params = params.replace(species=dom.shape[0] - 1)
        else:
            # default circulant: RPS for 3, C(S,{1,2}) for 5+, C(S,{1}) else
            offs = (1, 2) if params.species >= 5 else (1,)
            dom = dom_mod.circulant(params.species, offs)

    if args.trials:
        run_trial_batch(params.validate(), dom, args.trials,
                        args.trial_devices)
        return

    params = params.replace(mcs=params.mcs - start_mcs).validate()

    hooks = []
    if args.snapshot_every:
        def snap_hook(mcs_done, grid, cnts):
            if mcs_done % args.snapshot_every == 0:
                io_mod.save_snapshot(params.out_dir, np.asarray(grid),
                                     start_mcs + mcs_done)
        hooks.append(snap_hook)

    t0 = time.time()
    res = simulate(params, dom, grid0=grid0, key=key, hooks=hooks)
    dt = time.time() - t0

    n = params.n_cells
    total_mcs = start_mcs + res.mcs_completed
    print(f"[escg] {params.height}x{params.length} species={params.species}"
          f" engine={params.engine}: {res.mcs_completed} MCS in {dt:.2f}s"
          f" ({res.mcs_completed * n / max(dt, 1e-9):.3g} updates/s)")
    if res.stasis_mcs >= 0:
        print(f"[escg] stasis (monoculture/dead) at MCS "
              f"{start_mcs + res.stasis_mcs}")
    print("[escg] final densities:", np.round(res.densities[-1], 4))

    if params.save:
        os.makedirs(params.out_dir, exist_ok=True)
        io_mod.save_state(params.out_dir, params.replace(mcs=args.mcs),
                          res.grid, total_mcs, np.asarray(dom))
        io_mod.export_densities_csv(
            os.path.join(params.out_dir, "densities.csv"), res.densities)
        print(f"[escg] state + densities saved to {params.out_dir}")


if __name__ == "__main__":
    main()
