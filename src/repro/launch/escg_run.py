"""ESCG simulation driver — CLI-parity with the paper (Tables 3.1/3.2).

This is the production entry point for the paper's own workload: the
end-to-end driver of this framework's kind (simulation). Supports every
registered engine, --save/--resume state round-trips, dominance CSV import,
periodic snapshots and density export.

Beyond the paper's CLI it exposes the two scaling axes and their
composition (DESIGN.md §4-§6):

* ``--engine sharded [--shardGrid R C] [--localKernel pallas|fused]`` —
  one big lattice decomposed across devices (grid axis); ``--localKernel``
  selects the in-region tile sweep implementation: ``jnp``/``pallas`` are
  bit-identical to each other, ``fused`` derives proposals in-kernel from
  Philox counters keyed by global tile identity (zero proposal HBM
  traffic, bit-identical to ``--engine pallas_fused``).
* ``--trials N [--trialDevices D]`` — N IID replicate lattices, vmapped
  and sharded across devices over the trial axis (pod axis). Prints
  streamed survival / stasis statistics; with ``--save true`` the full
  ``TrialResult`` JSON lands in ``<outDir>/trials.json``. Results are
  bit-identical for any ``--trialDevices`` (per-trial fold-in PRNG keys).
* ``--trials N --engine sharded_pod --meshShape P,R,C`` — BOTH axes at
  once on a composed ('pod','rows','cols') mesh: trials shard over the
  pod axis while every trial's lattice is domain-decomposed over
  (rows, cols) with halo exchange. Bit-identical to the single-device
  run for any factorization.

The scenario layer (DESIGN.md §10) makes every registered study a one-flag
invocation: ``--scenario NAME`` pulls species count, dominance network,
action rates and boundary condition from the scenario registry
(``core/scenarios.py``); explicitly-passed physics flags override the
preset, and parametric families take a numeric suffix (``nspecies7``).
``--listScenarios [--markdown|--check README.md]`` prints/CI-checks the
registry-generated scenario matrix, exactly like ``--listEngines`` does
for engines.

Examples:
  python -m repro.launch.escg_run --scenario zhong_density --mcs 1000 \
      --length 64 --height 64          # Zhong ablated RPSLS, one flag
  python -m repro.launch.escg_run --scenario probabilistic --trials 64 \
      --mcs 10000                      # Park alliances, massed replication
  python -m repro.launch.escg_run --scenario nspecies7 --mcs 2000 \
      --engine sublattice --tile 8 16  # 7-species cyclic family
  python -m repro.launch.escg_run --listScenarios --markdown
  python -m repro.launch.escg_run --length 200 --height 200 --mcs 2000 \
      --engine batched --save true --outDir out/rps
  python -m repro.launch.escg_run --dominance dominance.csv --resume true \
      --outDir out/rps            # continue a saved run
  python -m repro.launch.escg_run --length 100 --height 100 --species 8 \
      --trials 64 --mcs 10000     # Park-style massed IID replication
  python -m repro.launch.escg_run --length 800 --height 800 --species 8 \
      --trials 16 --mcs 10000 --engine sharded_pod --meshShape 4,2,2 \
      --tile 8 32                 # massed replication of LARGE lattices
  python -m repro.launch.escg_run --length 800 --height 800 --species 8 \
      --trials 16 --mcs 10000 --engine sharded_pod --meshShape 4,2,2 \
      --tile 8 32 --localKernel fused   # same, zero proposal HBM traffic
  python -m repro.launch.escg_run --listEngines --markdown   # engine matrix
"""
from __future__ import annotations

import argparse
import os
import re
import time
from typing import Optional

import jax
import numpy as np

from ..core import dominance as dom_mod
from ..core import engines, scenarios
from ..core import io as io_mod
from ..core.params import EscgParams, add_cli_args, params_from_args
from ..core.simulation import simulate
from ..core.trials import run_trials

# ---------------------- registry matrices (docs) -------------------------- #
# Both README tables — engines and scenarios — are generated from their
# registries and CI-checked against drift with the same marker mechanism.

_MATRIX_HEAD = ("engine", "boundaries", "tile", "devices", "trial axis",
                "local kernels", "reproduces")
_MATRIX_BEGIN = ("<!-- engine-matrix:begin (generated: escg_run "
                 "--listEngines --markdown; CI-checked) -->")
_MATRIX_END = "<!-- engine-matrix:end -->"

_SC_MATRIX_HEAD = ("scenario", "species", "rates", "boundary", "init",
                   "observables", "reproduces")
_SC_MATRIX_BEGIN = ("<!-- scenario-matrix:begin (generated: escg_run "
                    "--listScenarios --markdown; CI-checked) -->")
_SC_MATRIX_END = "<!-- scenario-matrix:end -->"


def _markdown_table(head, rows) -> str:
    lines = ["| " + " | ".join(head) + " |", "|" + "---|" * len(head)]
    lines += ["| " + " | ".join(row) + " |" for row in rows]
    return "\n".join(lines)


def _readme_block_drift(readme_path: str, begin: str, end: str, want: str,
                        what: str, regen_flag: str) -> Optional[str]:
    """None when the README block between ``begin``/``end`` equals the
    registry-generated table; else a human-readable drift message."""
    with open(readme_path) as f:
        text = f.read()
    m = re.search(re.escape(begin) + r"\n(.*?)\n" + re.escape(end),
                  text, re.S)
    if not m:
        return f"{readme_path}: {what} markers not found"
    got = m.group(1).strip()
    if got != want.strip():
        return (f"{readme_path}: {what} drifted from the registry.\n"
                f"Regenerate with:\n  PYTHONPATH=src python -m "
                f"repro.launch.escg_run {regen_flag} --markdown\n"
                f"--- README ---\n{got}\n--- registry ---\n{want.strip()}")
    return None


def engine_matrix_rows():
    """One row per registered engine, derived purely from EngineCaps."""
    rows = []
    for spec in engines.engine_specs():
        c = spec.caps
        tile = ("must divide device blocks" if c.multi_device
                else "must divide lattice") if c.tiled else "—"
        rows.append((f"`{spec.name}`",
                     "flux only" if c.flux_only else "flux or reflect",
                     tile,
                     "multi" if c.multi_device else "single",
                     c.trial_axis,
                     ", ".join(f"`{k}`" for k in c.local_kernels) or "—",
                     f"{c.paper} — {c.description}"))
    return rows


def engine_matrix_markdown() -> str:
    """The README engine matrix, generated from the live registry."""
    return _markdown_table(_MATRIX_HEAD, engine_matrix_rows())


def readme_matrix_drift(readme_path: str) -> Optional[str]:
    """Engine-matrix drift check: used by ``--listEngines --check`` (CI)
    and tests/test_docs.py."""
    return _readme_block_drift(readme_path, _MATRIX_BEGIN, _MATRIX_END,
                               engine_matrix_markdown(), "engine matrix",
                               "--listEngines")


def scenario_matrix_rows():
    """One row per registered scenario, derived from ScenarioCaps."""
    rows = []
    for spec in scenarios.scenario_specs():
        c = spec.caps
        rows.append((f"`{spec.name}`",
                     "parametric (`S`)" if c.species is None
                     else str(c.species),
                     c.rates,
                     c.boundary,
                     c.init,
                     ", ".join(f"`{o}`" for o in c.observables) or "—",
                     f"{c.paper} — {c.description}"))
    return rows


def scenario_matrix_markdown() -> str:
    """The README scenario matrix, generated from the live registry."""
    return _markdown_table(_SC_MATRIX_HEAD, scenario_matrix_rows())


def readme_scenario_drift(readme_path: str) -> Optional[str]:
    """Scenario-matrix drift check: used by ``--listScenarios --check``
    (CI) and tests/test_docs.py."""
    return _readme_block_drift(readme_path, _SC_MATRIX_BEGIN,
                               _SC_MATRIX_END, scenario_matrix_markdown(),
                               "scenario matrix", "--listScenarios")


def print_engine_matrix() -> None:
    """Registry-driven engine table (plain-text variant)."""
    print(f"{'engine':<13} {'boundaries':<11} {'tiled':<6} {'devices':<8} "
          f"{'trial axis':<17} paper ref")
    for spec in engines.engine_specs():
        c = spec.caps
        print(f"{spec.name:<13} {'flux-only' if c.flux_only else 'any':<11} "
              f"{'yes' if c.tiled else 'no':<6} "
              f"{'multi' if c.multi_device else 'single':<8} "
              f"{c.trial_axis:<17} {c.paper}")
        print(f"{'':13} {spec.caps.description}")


def print_scenario_matrix() -> None:
    """Registry-driven scenario table (plain-text variant)."""
    print(f"{'scenario':<15} {'species':<9} {'rates':<14} {'boundary':<9} "
          "paper ref")
    for spec in scenarios.scenario_specs():
        c = spec.caps
        sp = "S (param)" if c.species is None else str(c.species)
        print(f"{spec.name:<15} {sp:<9} {c.rates:<14} {c.boundary:<9} "
              f"{c.paper}")
        print(f"{'':15} {c.description}")


# ------------------------------ trial mode -------------------------------- #

def run_trial_batch(params: EscgParams, dom: np.ndarray, n_trials: int,
                    trial_devices: Optional[int]) -> None:
    """--trials N: massed IID replication through the pod-axis driver."""
    def progress(mcs_done, alive_counts):
        in_stasis = int((alive_counts <= 1).sum())
        print(f"[escg]   chunk -> MCS {mcs_done}: {in_stasis}/{n_trials} "
              f"trials in stasis", flush=True)

    # scenario-first call form (DESIGN.md §10): the resolved params split
    # back into layers; the explicit run.observables tuple round-trips, so
    # composing reproduces `params` exactly
    sc, eng_cfg, run_cfg = scenarios.decompose(params)
    t0 = time.time()
    res = run_trials(sc, dom, n_trials, trial_devices=trial_devices,
                     hooks=[progress], engine=eng_cfg, run=run_cfg)
    dt = time.time() - t0

    upd = res.mcs_completed * params.n_cells * n_trials
    print(f"[escg] {n_trials} trials x {params.height}x{params.length} "
          f"species={params.species} engine={params.engine} on "
          f"{res.n_devices} device(s): {res.mcs_completed} MCS in {dt:.2f}s "
          f"({upd / max(dt, 1e-9):.3g} updates/s aggregate)")
    print(f"[escg] survival probabilities: "
          f"{np.round(res.survival_probabilities(), 4)}")
    print(f"[escg] survivors histogram:    "
          f"{np.round(res.survivors_hist(), 4)}")
    n_stasis = int((res.stasis_mcs >= 0).sum())
    if n_stasis:
        reached = res.stasis_mcs[res.stasis_mcs >= 0]
        print(f"[escg] stasis reached in {n_stasis}/{n_trials} trials "
              f"(median MCS {int(np.median(reached))})")
    if params.save:
        os.makedirs(params.out_dir, exist_ok=True)
        path = os.path.join(params.out_dir, "trials.json")
        with open(path, "w") as f:
            f.write(res.to_json())
        print(f"[escg] TrialResult saved to {path}")


# --------------------------------- main ----------------------------------- #

def build_parser() -> argparse.ArgumentParser:
    """The full CLI parser (paper flags + scaling + scenario layer) —
    exposed so tests can drive the exact ``--scenario`` resolution path."""
    ap = argparse.ArgumentParser(description="ESCG simulator (paper CLI)")
    add_cli_args(ap)
    ap.add_argument("--snapshotEvery", dest="snapshot_every", type=int,
                    default=0, help="save lattice snapshot every N MCS")
    ap.add_argument("--trials", type=int, default=0,
                    help="run N IID trials (vmapped, sharded across devices "
                         "over the trial axis) instead of one simulation; "
                         "prints survival/stasis statistics")
    ap.add_argument("--trialDevices", dest="trial_devices", type=int,
                    default=None,
                    help="pod width for --trials: number of local devices "
                         "to shard the trial axis across (default: all; "
                         "results are bit-identical for any value)")
    ap.add_argument("--scenario", type=str, default=None,
                    help="run a registered scenario preset (see "
                         "--listScenarios); its physics — species, "
                         "dominance network, rates, boundary — come from "
                         "the registry, overridden by explicitly-passed "
                         "flags; parametric families take a numeric "
                         "suffix (nspecies7)")
    ap.add_argument("--listEngines", dest="list_engines",
                    action="store_true",
                    help="print the registered engine matrix and exit")
    ap.add_argument("--listScenarios", dest="list_scenarios",
                    action="store_true",
                    help="print the registered scenario matrix and exit")
    ap.add_argument("--markdown", action="store_true",
                    help="with --listEngines/--listScenarios: print the "
                         "matrix as the markdown table embedded in "
                         "README.md")
    ap.add_argument("--check", dest="check_readme", metavar="README",
                    default=None,
                    help="with --listEngines/--listScenarios: exit "
                         "non-zero if README's matrix drifted from the "
                         "registry (CI)")
    return ap


def scenario_setup(args, ap: argparse.ArgumentParser):
    """Resolve ``--scenario``: (validated EscgParams, dominance matrix).
    Physics come from the registry preset, overridden by explicitly-passed
    scenario flags; engine/run control from the remaining CLI flags.
    Resolution goes through ``scenarios.resolve_config``, so the preset's
    ``ScenarioCaps.observables`` stream by default (DESIGN.md §11) unless
    ``--observables`` pins the set ('none' disables)."""
    sc = scenarios.scenario_from_cli(args, ap)
    params, dom = scenarios.resolve_config(
        sc, None, scenarios.engine_config_from_args(args),
        scenarios.run_config_from_args(args))
    return sc, params, dom


def main() -> None:
    ap = build_parser()
    args = ap.parse_args()

    if args.list_engines or args.list_scenarios:
        for flagged, drift_fn, md_fn, text_fn, what in (
                (args.list_engines, readme_matrix_drift,
                 engine_matrix_markdown, print_engine_matrix,
                 "engine matrix"),
                (args.list_scenarios, readme_scenario_drift,
                 scenario_matrix_markdown, print_scenario_matrix,
                 "scenario matrix")):
            if not flagged:
                continue
            if args.check_readme:
                drift = drift_fn(args.check_readme)
                if drift:
                    raise SystemExit(drift)
                print(f"[escg] {args.check_readme} {what} matches the "
                      "registry")
            elif args.markdown:
                print(md_fn())
            else:
                text_fn()
        return

    grid0 = None
    key = None
    start_mcs = 0
    if args.resume:
        if args.trials:
            raise SystemExit("--trials and --resume are mutually exclusive "
                             "(trial batches keep no host-side state)")
        if args.scenario:
            raise SystemExit("--scenario and --resume are mutually "
                             "exclusive (the resumed state already "
                             "carries its physics)")
        params, grid0, start_mcs, dom, key_arr = io_mod.load_state(
            args.out_dir)
        params = params.replace(resume=True)
        key = (jax.numpy.asarray(key_arr) if key_arr is not None
               else jax.random.fold_in(jax.random.PRNGKey(params.seed),
                                       start_mcs))
        # allow the CLI to extend the run beyond the saved target
        params = params.replace(mcs=max(params.mcs, args.mcs))
        print(f"[escg] resumed {args.out_dir} at MCS {start_mcs}")
    elif args.scenario:
        # scenario layer (DESIGN.md §10): physics from the registry,
        # engine/run control from the CLI; explicitly-passed scenario
        # flags (--species, --mobility, ...) override the preset
        if args.dominance:
            raise SystemExit("--scenario and --dominance are mutually "
                             "exclusive (the scenario defines its own "
                             "dominance network)")
        sc, params, dom = scenario_setup(args, ap)
        print(f"[escg] scenario {sc.name!r}: species={sc.species} "
              f"rates={scenarios.get_scenario(sc.name).caps.rates} "
              f"boundary={sc.boundary}")
    else:
        params = params_from_args(args)
        if args.dominance:
            with open(args.dominance) as f:
                dom = dom_mod.from_csv(f.read())
            params = params.replace(species=dom.shape[0] - 1)
        else:
            # default circulant: RPS for 3, C(S,{1,2}) for 5+, C(S,{1}) else
            offs = (1, 2) if params.species >= 5 else (1,)
            dom = dom_mod.circulant(params.species, offs)

    if args.trials:
        run_trial_batch(params.validate(), dom, args.trials,
                        args.trial_devices)
        return

    params = params.replace(mcs=params.mcs - start_mcs).validate()

    hooks = []
    if args.snapshot_every:
        def snap_hook(mcs_done, grid, cnts):
            if mcs_done % args.snapshot_every == 0:
                io_mod.save_snapshot(params.out_dir, np.asarray(grid),
                                     start_mcs + mcs_done)
        hooks.append(snap_hook)

    if params.print_frequency > 0:
        # periodic density print (paper printFrequency). The per-MCS rows
        # arrive once per chunk — flushed from the device observable ring
        # when the pipeline is on (DESIGN.md §11) — so printing any
        # interval costs zero extra host transfers.
        pf, n_cells = params.print_frequency, params.n_cells

        def density_hook(mcs_done, grid, cnts):
            first = mcs_done - len(cnts) + 1
            for i in range((-first % pf), len(cnts), pf):
                print(f"[escg] MCS {start_mcs + first + i}: densities "
                      f"{np.round(cnts[i] / n_cells, 4)}", flush=True)
        hooks.append(density_hook)

    # scenario-first call form (DESIGN.md §10); decompose round-trips the
    # resolved params exactly, observables included
    sc_run, eng_cfg, run_cfg = scenarios.decompose(params)
    t0 = time.time()
    res = simulate(sc_run, dom, grid0=grid0, key=key, hooks=hooks,
                   engine=eng_cfg, run=run_cfg)
    dt = time.time() - t0

    n = params.n_cells
    total_mcs = start_mcs + res.mcs_completed
    print(f"[escg] {params.height}x{params.length} species={params.species}"
          f" engine={params.engine}: {res.mcs_completed} MCS in {dt:.2f}s"
          f" ({res.mcs_completed * n / max(dt, 1e-9):.3g} updates/s)")
    if res.stasis_mcs >= 0:
        print(f"[escg] stasis (monoculture/dead) at MCS "
              f"{start_mcs + res.stasis_mcs}")
    print("[escg] final densities:", np.round(res.densities[-1], 4))

    if params.save:
        os.makedirs(params.out_dir, exist_ok=True)
        io_mod.save_state(params.out_dir, params.replace(mcs=args.mcs),
                          res.grid, total_mcs, np.asarray(dom))
        io_mod.export_densities_csv(
            os.path.join(params.out_dir, "densities.csv"), res.densities)
        print(f"[escg] state + densities saved to {params.out_dir}")


if __name__ == "__main__":
    main()
