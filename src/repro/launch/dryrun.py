import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import including `from repro...` — jax locks the
#   device count on first init (brief: MULTI-POD DRY-RUN step 0).

import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from typing import Any, Dict, Optional  # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from ..configs import ARCHS, SHAPES, cell_is_runnable, get_arch  # noqa: E402
from ..models import spec as spec_mod  # noqa: E402
from ..models.registry import build_model  # noqa: E402
from ..parallel import roofline  # noqa: E402
from ..parallel.ctx import activation_sharding  # noqa: E402
from ..parallel.sharding import make_rules, named_sharding_tree  # noqa: E402
from ..runtime import train_lib  # noqa: E402
from .mesh import make_production_mesh, n_chips  # noqa: E402

ESCG_ARCH = "escg-lattice"       # the paper's own workload, dry-run as well


def _memory_analysis_dict(compiled) -> Dict[str, Any]:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:                                  # noqa: BLE001
        return {"error": str(e)}
    if ma is None:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    out["total_bytes_per_device"] = (
        out.get("argument_size_in_bytes", 0)
        + out.get("temp_size_in_bytes", 0)
        + out.get("output_size_in_bytes", 0)
        - out.get("alias_size_in_bytes", 0))
    return out


def _cost_dict(compiled) -> Optional[Dict[str, float]]:
    try:
        ca = compiled.cost_analysis()
    except Exception:                                       # noqa: BLE001
        return None
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else None
    return dict(ca) if ca else None


def _compile_cell(cfg, shape, mesh, rules):
    """Lower + compile one variant; returns (compiled, n_tokens)."""
    model = build_model(cfg)
    brule = rules.get("batch")

    def batch_shardings(in_specs):
        return {k: NamedSharding(
            mesh, P(*((brule,) + (None,) * (len(v.shape) - 1))))
            for k, v in in_specs.items()}

    with mesh, activation_sharding(mesh, rules):
        in_specs = model.input_specs(shape)
        batch_sh = batch_shardings(in_specs)
        if shape.kind == "train":
            sspecs = train_lib.state_specs(model)
            state_sh = named_sharding_tree(sspecs, mesh, rules)
            lowered = jax.jit(
                train_lib.make_train_step(model),
                in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh, None),
                donate_argnums=(0,),
            ).lower(spec_mod.abstract(sspecs), in_specs)
            n_tokens = shape.global_batch * shape.seq_len
        elif shape.kind == "prefill":
            params_sh = named_sharding_tree(model.param_specs, mesh, rules)
            lowered = jax.jit(
                train_lib.make_prefill_step(model, max_len=shape.seq_len),
                in_shardings=(params_sh, batch_sh),
            ).lower(model.abstract_params(), in_specs)
            n_tokens = shape.global_batch * shape.seq_len
        else:                                   # decode
            params_sh = named_sharding_tree(model.param_specs, mesh, rules)
            cache_specs = model.cache_specs(shape.global_batch,
                                            shape.seq_len)
            cache_sh = named_sharding_tree(cache_specs, mesh, rules)
            lowered = jax.jit(
                train_lib.make_decode_step(model),
                in_shardings=(params_sh, cache_sh, batch_sh),
                out_shardings=(None, cache_sh),
                donate_argnums=(1,),
            ).lower(model.abstract_params(),
                    spec_mod.abstract(cache_specs), in_specs)
            n_tokens = shape.global_batch       # one token per sequence
        compiled = lowered.compile()
    return compiled, n_tokens


def _extract_cost(compiled):
    cost = _cost_dict(compiled) or {}
    coll = roofline.collective_bytes(compiled.as_text())
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)), coll)


def loop_corrected_cost(cfg, shape, mesh, rules):
    """XLA HloCostAnalysis counts a while-loop body ONCE, so a scanned
    L-layer module undercounts flops/bytes/collectives by ~L. Correction:
    compile UNROLLED 1-unit and 2-unit variants; per-unit cost is their
    difference; total = c1 + (n_units - 1) * (c2 - c1). For zamba2 a unit is
    one group of `attn_every` mamba blocks + one shared-attention
    application; for whisper enc and dec layers scale together."""
    unit = cfg.attn_every if cfg.family == "hybrid" else 1
    n_units = cfg.n_layers / unit

    def small(n):
        kw = dict(n_layers=n * unit, scan_layers=False)
        if cfg.family == "encdec":
            kw["enc_layers"] = n
        return cfg.replace(**kw)

    c1, _ = _compile_cell(small(1), shape, mesh, rules)
    f1, b1, coll1 = _extract_cost(c1)
    c2, _ = _compile_cell(small(2), shape, mesh, rules)
    f2, b2, coll2 = _extract_cost(c2)
    scale = n_units - 1.0
    flops = f1 + scale * (f2 - f1)
    byts = b1 + scale * (b2 - b1)
    coll = {k: coll1[k] + scale * (coll2[k] - coll1[k]) for k in coll1}
    return flops, byts, coll


def lower_lm_cell(arch: str, shape_name: str, multi_pod: bool,
                  rule_overrides: Optional[Dict[str, Any]] = None,
                  cfg_overrides: Optional[Dict[str, Any]] = None
                  ) -> Dict[str, Any]:
    """Lower + compile one (arch x shape x mesh) cell; return the record."""
    cfg = get_arch(arch)
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    shape = SHAPES[shape_name]
    ok, why = cell_is_runnable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi_pod" if multi_pod else "single_pod",
                "status": "skipped", "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = n_chips(mesh)
    model = build_model(cfg)
    overrides = dict(cfg.rule_overrides)
    if rule_overrides:
        overrides.update(rule_overrides)
    rules = make_rules(mesh, overrides, shape.kind, shape.global_batch)

    t0 = time.time()
    compiled, n_tokens = _compile_cell(cfg, shape, mesh, rules)
    f_raw, b_raw, coll_raw = _extract_cost(compiled)
    memory = _memory_analysis_dict(compiled)
    del compiled
    flops, byts, coll = loop_corrected_cost(cfg, shape, mesh, rules)
    elapsed = time.time() - t0

    kind = "train" if shape.kind == "train" else "serve"
    terms = roofline.roofline_terms(flops, byts, float(sum(coll.values())),
                                    chips)
    mf = roofline.model_flops(model.n_active_params(), n_tokens, kind)
    terms["model_flops_total"] = mf
    terms["model_flops_per_chip"] = mf / chips
    terms["useful_flops_ratio"] = (mf / chips) / flops if flops else 0.0
    terms["collective_breakdown"] = coll
    return {
        "arch": arch, "shape": shape_name,
        "mesh": "multi_pod" if multi_pod else "single_pod",
        "chips": chips, "status": "ok",
        "compile_s": round(elapsed, 1),
        "n_params": model.n_params(),
        "n_active_params": model.n_active_params(),
        "n_tokens": n_tokens,
        "memory": memory,
        "cost_raw_scanned": {"flops": f_raw, "bytes": b_raw,
                             "note": "while-loop bodies counted once"},
        "roofline": terms,
    }


def lower_escg_cell(multi_pod: bool, lattice: int = 16384,
                    tile=(8, 128), species: int = 5) -> Dict[str, Any]:
    """Dry-run the paper's own workload: one sublattice round on a lattice
    2-D-sharded over (data x model); pod axis = vmapped IID trials."""
    from ..core import dominance
    from ..core.sublattice import run_round

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = n_chips(mesh)
    th, tw = tile
    n_trials = mesh.shape.get("pod", 1)
    h = w = lattice
    n_tiles = (h // th) * (w // tw)
    k_per = (h * w) // n_tiles
    t0 = time.time()

    grid_spec = jax.ShapeDtypeStruct((n_trials, h, w), jnp.int32)
    prop_i = jax.ShapeDtypeStruct((n_trials, n_tiles, k_per), jnp.int32)
    prop_f = jax.ShapeDtypeStruct((n_trials, n_tiles, k_per), jnp.float32)
    dom = dominance.circulant(species, (1, 2))
    # NB: the torus shift is lowered as a constant — a traced shift turns
    # jnp.roll into a device-spanning gather under vmap; the collective
    # structure (edge-sliver permutes) is identical for every shift value.
    shift = jnp.array([3, 5], jnp.int32)

    grid_sh = NamedSharding(mesh, P("pod", "data", "model") if multi_pod
                            else P(None, "data", "model"))
    prop_sh = NamedSharding(mesh, P("pod" if multi_pod else None, None,
                                    None))

    from ..core.rng import ProposalBatch
    t_eps, t_eps_mu = 0.2, 0.6

    def round_fn(grid, cell, dirn, ua, ud):
        f = lambda g, c, d, a, u: run_round(
            g, ProposalBatch(c, d, a, u), shift, (th, tw), t_eps, t_eps_mu,
            jnp.asarray(dom), roll_back=False)   # §Perf H3 iter-1
        return jax.vmap(f)(grid, cell, dirn, ua, ud)

    with mesh:
        lowered = jax.jit(
            round_fn,
            in_shardings=(grid_sh, prop_sh, prop_sh, prop_sh, prop_sh),
            out_shardings=grid_sh,
            donate_argnums=(0,),
        ).lower(grid_spec, prop_i, prop_i, prop_f, prop_f)
        compiled = lowered.compile()

    cost = _cost_dict(compiled)
    hlo = compiled.as_text()
    updates = n_trials * n_tiles * k_per
    terms = roofline.summarize(cost, hlo, chips, 0, 1, "serve")
    terms["updates_per_round"] = updates
    return {
        "arch": ESCG_ARCH, "shape": f"L{lattice}_tile{th}x{tw}",
        "mesh": "multi_pod" if multi_pod else "single_pod",
        "chips": chips, "status": "ok",
        "compile_s": round(time.time() - t0, 1),
        "memory": _memory_analysis_dict(compiled),
        "cost": {k: cost[k] for k in ("flops", "bytes accessed")
                 if cost and k in cost},
        "roofline": terms,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", type=str, default="all",
                    help="arch id, 'all', or 'escg'")
    ap.add_argument("--shape", type=str, default="all")
    ap.add_argument("--mesh", type=str, default="both",
                    choices=("single_pod", "multi_pod", "both"))
    ap.add_argument("--out", type=str, default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--escg-lattice", type=int, default=16384)
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    archs = list(ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = (["single_pod", "multi_pod"] if args.mesh == "both"
              else [args.mesh])

    cells = []
    for mp in meshes:
        for arch in archs:
            if arch == "escg":
                cells.append((ESCG_ARCH, f"L{args.escg_lattice}", mp))
                continue
            for shape in shapes:
                cells.append((arch, shape, mp))

    n_ok = n_fail = n_skip = 0
    for arch, shape, mp in cells:
        tag = f"{arch}__{shape}__{mp}".replace("/", "_")
        path = os.path.join(args.out, tag + ".json")
        if os.path.exists(path) and not args.force:
            print(f"[dryrun] cached {tag}")
            continue
        print(f"[dryrun] lowering {tag} ...", flush=True)
        try:
            if arch == ESCG_ARCH:
                rec = lower_escg_cell(mp == "multi_pod",
                                      lattice=args.escg_lattice)
            else:
                rec = lower_lm_cell(arch, shape, mp == "multi_pod")
            status = rec["status"]
        except Exception as e:                              # noqa: BLE001
            rec = {"arch": arch, "shape": shape, "mesh": mp,
                   "status": "error", "error": str(e),
                   "traceback": traceback.format_exc()[-4000:]}
            status = "error"
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        if status == "ok":
            n_ok += 1
            mem = rec.get("memory", {}).get("total_bytes_per_device", 0)
            dom = rec.get("roofline", {}).get("dominant", "?")
            print(f"[dryrun]   ok {tag}: {mem/2**30:.2f} GiB/dev, "
                  f"dominant={dom}, compile={rec['compile_s']}s",
                  flush=True)
        elif status == "skipped":
            n_skip += 1
            print(f"[dryrun]   skipped {tag}: {rec['reason']}")
        else:
            n_fail += 1
            print(f"[dryrun]   ERROR {tag}: {rec['error'][:300]}")
    print(f"[dryrun] done ok={n_ok} skip={n_skip} fail={n_fail}")


if __name__ == "__main__":
    main()
