"""Production mesh construction (brief: MULTI-POD DRY-RUN step 1).

A FUNCTION, not a module-level constant — importing this module never touches
jax device state."""
from __future__ import annotations

import jax

try:  # jax >= 0.5: explicit axis types
    from jax.sharding import AxisType
except ImportError:  # jax 0.4.x: every axis is Auto; no kwarg needed
    AxisType = None


def _mesh(shape, axes):
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh helper (tests, elastic remesh)."""
    return _mesh(tuple(shape), tuple(axes))


def make_composed_mesh(mesh_shape=None, *, height: int = 0, width: int = 0,
                       tile=(8, 32)):
    """The ESCG composed trial x grid mesh, ``('pod', 'rows', 'cols')``
    (DESIGN.md §6). Thin wrapper over ``parallel.sharding.pod_lattice_mesh``
    so launch scripts build it the same way the sharded_pod engine does;
    pass height/width/tile to get the tile-divisibility validation, or
    leave them 0 to skip it (pure layout construction)."""
    from ..parallel.sharding import pod_lattice_mesh

    if not height or not width:
        import jax as _jax
        n = len(_jax.devices())
        shape = tuple(mesh_shape) if mesh_shape is not None else (n, 1, 1)
        return _mesh(shape, ("pod", "rows", "cols"))
    return pod_lattice_mesh(mesh_shape, height, width, tile[0], tile[1])


def n_chips(mesh) -> int:
    return int(mesh.devices.size)
