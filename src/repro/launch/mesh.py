"""Production mesh construction (brief: MULTI-POD DRY-RUN step 1).

A FUNCTION, not a module-level constant — importing this module never touches
jax device state."""
from __future__ import annotations

import jax

try:  # jax >= 0.5: explicit axis types
    from jax.sharding import AxisType
except ImportError:  # jax 0.4.x: every axis is Auto; no kwarg needed
    AxisType = None


def _mesh(shape, axes):
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh helper (tests, elastic remesh)."""
    return _mesh(tuple(shape), tuple(axes))


def n_chips(mesh) -> int:
    return int(mesh.devices.size)
