"""Lattice construction and neighbour indexing (paper §3.1.1).

The grid is a (H, W) int32 array; 0 = empty, 1..S = species. Like the paper we
keep a flat-index view for proposal streams: ``index = row * W + col``.
Boundary handling: ``flux=True`` -> periodic wrap (modular arithmetic, the
paper's default); ``flux=False`` -> reflect (clamp to edge; an out-of-bounds
neighbour maps back to the nearest edge cell).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Direction tables. First 4 entries = von Neumann (up, down, left, right,
# matching the paper's ordering); entries 4..7 add the Moore diagonals.
DIRS = np.array(
    [(-1, 0), (1, 0), (0, -1), (0, 1),
     (-1, -1), (-1, 1), (1, -1), (1, 1)], dtype=np.int32)


def init_grid(key: jax.Array, height: int, width: int, species: int,
              empty_prob: float = 0.0, dtype=jnp.int32) -> jax.Array:
    """Uniform random initialization (paper §3.1.1): each cell is empty with
    probability ``empty_prob`` else uniform over species 1..S."""
    k1, k2 = jax.random.split(key)
    occupied = jax.random.uniform(k1, (height, width)) >= empty_prob
    labels = jax.random.randint(k2, (height, width), 1, species + 1,
                                dtype=jnp.int32)
    return jnp.where(occupied, labels, 0).astype(dtype)


def neighbor_rc(row: jax.Array, col: jax.Array, direction: jax.Array,
                height: int, width: int, flux: bool
                ) -> Tuple[jax.Array, jax.Array]:
    """Neighbour (row, col) for a direction id, under the boundary rule."""
    dirs = jnp.asarray(DIRS)
    dr = dirs[direction, 0]
    dc = dirs[direction, 1]
    nr, nc = row + dr, col + dc
    if flux:
        nr = jnp.mod(nr + height, height)
        nc = jnp.mod(nc + width, width)
    else:
        nr = jnp.clip(nr, 0, height - 1)
        nc = jnp.clip(nc, 0, width - 1)
    return nr, nc


def neighbor_index(cell: jax.Array, direction: jax.Array, height: int,
                   width: int, flux: bool) -> jax.Array:
    """Flat-index neighbour lookup (paper's modular-arithmetic formulas)."""
    row, col = cell // width, cell % width
    nr, nc = neighbor_rc(row, col, direction, height, width, flux)
    return nr * width + nc


def counts(grid: jax.Array, species: int) -> jax.Array:
    """Population counts per label 0..S (0 = empties). Device-resident."""
    return jnp.bincount(grid.reshape(-1).astype(jnp.int32),
                        length=species + 1)


def densities(grid: jax.Array, species: int) -> jax.Array:
    return counts(grid, species) / grid.size
