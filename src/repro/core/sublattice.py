"""E3: shifted-window synchronous-sublattice engine — the TPU-native redesign
of the paper's maxStep (DESIGN.md §2).

The torus is cut into (th x tw) tiles. Each round:
  1. a uniform random shift (dy, dx) in [0,th) x [0,tw) is applied to the
     torus (``jnp.roll`` — under pjit this moves only edge slivers between
     devices);
  2. every tile runs its K proposals **sequentially** (race-free by
     construction) while all tiles run in parallel; proposal cells are
     restricted to the tile interior (inset 1) so no tile writes outside
     itself — cross-tile conflicts are impossible, no atomics needed;
  3. the shift is rolled back (or accumulated — densities are
     translation-invariant, see the perf log).

Randomizing the sublattice origin each round restores ergodicity (Shim & Amar
2005). This module is the pure-jnp implementation; ``repro.kernels.escg_update``
is the Pallas version with explicit VMEM tiling, validated against
``tile_update`` below.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .lattice import DIRS
from .rng import ProposalBatch
from .rules import apply_pair


def tile_update(tile: jax.Array, props: ProposalBatch, t_eps: float,
                t_eps_mu: float, dom: jax.Array) -> jax.Array:
    """Sequentially apply K interior proposals to one (th, tw) tile.

    ``props.cell`` indexes the (th-2)x(tw-2) interior window; the chosen
    neighbour is then always inside the tile for both 4- and 8-neighbourhoods.
    This function is the oracle for the Pallas kernel.
    """
    th, tw = tile.shape
    iw = tw - 2
    dirs = jnp.asarray(DIRS)

    def body(t, p):
        cell, dirn, ua, ud = p
        r = 1 + cell // iw
        c = 1 + cell % iw
        nr = r + dirs[dirn, 0]
        nc = c + dirs[dirn, 1]
        s = t[r, c]
        n = t[nr, nc]
        ns, nn = apply_pair(s, n, ua, ud, t_eps, t_eps_mu, dom)
        t = t.at[r, c].set(ns)
        t = t.at[nr, nc].set(nn)
        return t, None

    tile, _ = lax.scan(body, tile,
                       (props.cell, props.dirn, props.u_act, props.u_dom))
    return tile


def to_tiles(grid: jax.Array, th: int, tw: int) -> jax.Array:
    """(H, W) -> (T, th, tw), raster tile order."""
    h, w = grid.shape
    return (grid.reshape(h // th, th, w // tw, tw)
                .transpose(0, 2, 1, 3)
                .reshape(-1, th, tw))


def from_tiles(tiles: jax.Array, h: int, w: int) -> jax.Array:
    t, th, tw = tiles.shape
    return (tiles.reshape(h // th, w // tw, th, tw)
                 .transpose(0, 2, 1, 3)
                 .reshape(h, w))


@partial(jax.jit, static_argnames=("tile_shape", "t_eps", "t_eps_mu",
                                   "roll_back"))
def run_round(grid: jax.Array, props: ProposalBatch, shift: jax.Array,
              tile_shape: Tuple[int, int], t_eps: float, t_eps_mu: float,
              dom: jax.Array, roll_back: bool = True) -> jax.Array:
    """One shifted-window round over the whole lattice (pure-jnp engine).

    ``props`` arrays have shape (T, K). Requires periodic boundaries (the
    roll assumes a torus); reflect boundaries use E1/E2.
    """
    h, w = grid.shape
    th, tw = tile_shape
    g = jnp.roll(grid, (-shift[0], -shift[1]), (0, 1))
    tiles = to_tiles(g, th, tw)
    upd = jax.vmap(lambda t, c, d, ua, ud: tile_update(
        t, ProposalBatch(c, d, ua, ud), t_eps, t_eps_mu, dom))
    tiles = upd(tiles, props.cell, props.dirn, props.u_act, props.u_dom)
    g = from_tiles(tiles, h, w)
    if roll_back:
        g = jnp.roll(g, (shift[0], shift[1]), (0, 1))
    return g
