"""Dominance-network builders (paper §3.1.1, §4.3).

A dominance network over S species is stored as an (S+1, S+1) float32 matrix
``D`` where ``D[i, j]`` is the probability that species ``i`` kills species
``j`` on an interaction event. Row/column 0 belong to the *empty* site and are
always zero — this removes every emptiness branch from the inner update rule
(the kernels index ``D`` directly with raw cell values).

Deterministic networks (the classic ESCGs) use probabilities in {0, 1};
probabilistic networks (Park, Chen & Szolnoki 2023) use rates in [0, 1].
"""
from __future__ import annotations

import csv
import io
from typing import Iterable, Sequence, Tuple

import numpy as np

__all__ = [
    "circulant", "ablate", "from_dense", "to_csv", "from_csv",
    "park_alliance_network", "RPS", "RPSLS", "zhong_ablated_rpsls",
]


def from_dense(mat: np.ndarray) -> np.ndarray:
    """Embed an (S, S) species-only matrix into the (S+1, S+1) padded form."""
    mat = np.asarray(mat, dtype=np.float32)
    s = mat.shape[0]
    if mat.shape != (s, s):
        raise ValueError("dominance matrix must be square")
    out = np.zeros((s + 1, s + 1), dtype=np.float32)
    out[1:, 1:] = mat
    return out


def circulant(species: int, offsets: Sequence[int] = (1,),
              rate: float = 1.0) -> np.ndarray:
    """Circulant dominance graph C(S, K) (paper eq. in §3.1.1).

    ``D[i][j] = rate`` iff ``(j - i + S) mod S in K`` (0-indexed species).
    RPS = C(3, {1});  RPSLS = C(5, {1, 2}).
    """
    if species < 1:
        raise ValueError("species >= 1")
    ks = set(int(k) % species for k in offsets)
    if 0 in ks:
        raise ValueError("offset 0 (self-dominance) not allowed")
    m = np.zeros((species, species), dtype=np.float32)
    for i in range(species):
        for k in ks:
            m[i, (i + k) % species] = rate
    return from_dense(m)


def ablate(dom: np.ndarray, edges: Iterable[Tuple[int, int]]) -> np.ndarray:
    """Remove directed edges (winner, loser), 1-indexed species ids."""
    out = np.array(dom, copy=True)
    for w, l in edges:
        if not (1 <= w < out.shape[0] and 1 <= l < out.shape[0]):
            raise ValueError(f"edge ({w},{l}) out of range")
        out[w, l] = 0.0
    return out


# ----------------------------- named presets ----------------------------- #

def RPS() -> np.ndarray:
    return circulant(3, (1,))


# The canonical embedding of real RPSLS into the circulant C(5, {1, 2})
# ("species i beats i+1 and i+2") orders the species as:
ROCK, SCISSORS, LIZARD, PAPER, SPOCK = 1, 2, 3, 4, 5
# check: Rock>Scissors,Lizard; Scissors>Lizard,Paper; Lizard>Paper,Spock;
#        Paper>Spock,Rock; Spock>Rock,Scissors  — all ten real RPSLS edges.


def RPSLS() -> np.ndarray:
    """Rock-Paper-Scissors-Lizard-Spock = C(5, {1, 2}) (paper Fig 3.1)."""
    return circulant(5, (1, 2))


def zhong_ablated_rpsls() -> np.ndarray:
    """Zhong et al. (2022) Fig 2: RPSLS with the Rock-crushes-Scissors edge
    removed (paper §3.1.2). In C(5,{1,2}) ordering that edge is
    (ROCK, SCISSORS) = (1, 2); the species observed to go extinct within
    200-600 MCS is PAPER (= id 4 here).
    """
    return ablate(RPSLS(), [(ROCK, SCISSORS)])


def park_alliance_network(alpha: float, beta: float,
                          gamma: float = 1.0) -> np.ndarray:
    """Eight-species network of Park, Chen & Szolnoki (2023) (paper Fig 4.8).

    Construction (documented reconstruction — the dissertation itself reports
    Park et al.'s description as ambiguous, §4.3.2):
      * gamma: Lotka-Volterra ring, species i beats i+1 (mod 8);
      * alpha: intra-alliance 4-cycles, species i beats i+2 (mod 8), which
        splits the ring into alliances A = {1,3,5,7} and B = {2,4,6,8};
      * beta : symmetry-breaking extra edges in ONE alliance only —
        diagonals of alliance A: i -> i+4 for i in {1, 3, 5, 7}.
    All edges are probabilistic interaction rates.
    """
    s = 8
    m = np.zeros((s, s), dtype=np.float32)
    for i in range(s):                      # 0-indexed internally
        m[i, (i + 1) % s] = gamma
        m[i, (i + 2) % s] = alpha
    for i in (0, 2, 4, 6):                  # alliance A = species 1,3,5,7
        m[i, (i + 4) % s] = max(m[i, (i + 4) % s], beta)
    return from_dense(m)


# --------------------------------- csv ----------------------------------- #

def to_csv(dom: np.ndarray) -> str:
    """Serialize the species-only (S, S) block as CSV (paper dominance.csv)."""
    buf = io.StringIO()
    w = csv.writer(buf)
    for row in np.asarray(dom)[1:, 1:]:
        w.writerow([f"{v:g}" for v in row])
    return buf.getvalue()


def from_csv(text: str) -> np.ndarray:
    rows = [r for r in csv.reader(io.StringIO(text)) if r]
    mat = np.array([[float(v) for v in r] for r in rows], dtype=np.float32)
    return from_dense(mat)


def n_species(dom: np.ndarray) -> int:
    return int(dom.shape[0]) - 1
