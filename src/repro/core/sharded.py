"""Multi-device ESCG: 2-D spatial domain decomposition (DESIGN.md §5).

The lattice shards as P('data', 'model') — a (16 x 16) pod holds a 256-tile
device grid. One round:

  1. ``jnp.roll`` by the random sublattice shift at the pjit level — GSPMD
     moves only the wrapped slivers between neighbouring devices
     (collective-permute of O(shift x perimeter) bytes, NOT a halo exchange
     per elementary step);
  2. ``shard_map`` local update: every device runs the same per-tile
     sequential sweeps as the single-device engine on its local block.
     Because proposals are restricted to tile interiors and device blocks
     are unions of tiles, no device ever writes another device's cells —
     the engine is communication-free inside a round by construction;
  3. roll back (optional — densities are translation-invariant, so
     production keeps the accumulated shift and only unrolls for
     snapshots; see §Perf).

Bit-exactness: a sharded round equals the single-device
``sublattice.run_round`` with identical proposals (tests/test_sharded.py
runs this equality on a subprocess-faked 16-device mesh).

The 'pod' axis carries vmapped IID trials — the paper's statistics problem
(2000 independent runs, §4.3.2) sharded across pods.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from .rng import ProposalBatch
from .sublattice import from_tiles, tile_update, to_tiles


def sharded_run_round(grid: jax.Array, props: ProposalBatch,
                      shift: jax.Array, tile_shape: Tuple[int, int],
                      t_eps: float, t_eps_mu: float, dom: jax.Array,
                      mesh: Mesh, row_axis: str = "data",
                      col_axis: str = "model",
                      roll_back: bool = True) -> jax.Array:
    """One shifted-window round on a (H, W) lattice sharded over
    (row_axis, col_axis). props arrays: (T, K) in global raster tile order.
    """
    h, w = grid.shape
    th, tw = tile_shape
    gh, gw = h // th, w // tw
    dr = mesh.shape[row_axis]
    dc = mesh.shape[col_axis]
    if (h // dr) % th or (w // dc) % tw:
        raise ValueError("device blocks must be unions of tiles")

    grid_spec = P(row_axis, col_axis)
    prop_spec = P(row_axis, col_axis, None)

    def reshape_props(a):
        return a.reshape(gh, gw, -1)

    def local_update(gl, cell, dirn, ua, ud):
        tiles = to_tiles(gl, th, tw)
        k = cell.shape[-1]
        upd = jax.vmap(lambda t, c, d, a, u: tile_update(
            t, ProposalBatch(c, d, a, u), t_eps, t_eps_mu, dom))
        tiles = upd(tiles, cell.reshape(-1, k), dirn.reshape(-1, k),
                    ua.reshape(-1, k), ud.reshape(-1, k))
        return from_tiles(tiles, gl.shape[0], gl.shape[1])

    update = shard_map(
        local_update, mesh=mesh,
        in_specs=(grid_spec, prop_spec, prop_spec, prop_spec, prop_spec),
        out_specs=grid_spec)

    g = jnp.roll(grid, (-shift[0], -shift[1]), (0, 1))
    g = update(g, reshape_props(props.cell), reshape_props(props.dirn),
               reshape_props(props.u_act), reshape_props(props.u_dom))
    if roll_back:
        g = jnp.roll(g, (shift[0], shift[1]), (0, 1))
    return g


def make_sharded_simulation(params, dom, mesh: Mesh,
                            row_axis: str = "data",
                            col_axis: str = "model"):
    """Returns (grid_sharding, jitted one_mcs(grid, key) -> grid) for the
    production mesh. Mirrors simulation.build_mcs_fn for the sharded case."""
    from . import rng as rngm

    p = params.validate()
    if p.engine not in ("sublattice", "pallas"):
        raise ValueError("sharded ESCG uses the sublattice engine")
    t_eps, t_eps_mu = p.action_thresholds()
    th, tw = p.tile
    n_tiles = (p.height // th) * (p.length // tw)
    k_per = max(1, -(-p.n_cells // n_tiles))
    interior = (th - 2) * (tw - 2)
    dom_j = jnp.asarray(dom, jnp.float32)
    grid_sh = NamedSharding(mesh, P(row_axis, col_axis))

    @jax.jit
    def one_mcs(grid, key):
        kp, ks = jax.random.split(key)
        props = rngm.tile_proposal_batch(kp, n_tiles, k_per, interior,
                                         p.neighbourhood)
        shift = rngm.round_shift(ks, th, tw)
        return sharded_run_round(grid, props, shift, (th, tw), t_eps,
                                 t_eps_mu, dom_j, mesh, row_axis, col_axis)

    return grid_sh, one_mcs
