"""Multi-device ESCG: 2-D spatial domain decomposition with explicit halo
exchange (DESIGN.md §5; the ROADMAP "sharding" north-star).

The lattice shards as P('rows', 'cols') over a device grid (dr, dc). One
round, entirely inside a single ``shard_map`` region:

  1. **halo exchange**: the random sublattice shift (dy, dx) in
     [0,th) x [0,tw) is realized as a static-size halo — each device
     ``ppermute``s its first ``th`` rows (resp. ``tw`` cols) to the
     neighbouring device and dynamic-slices the shifted window out of the
     extended block. O(halo x perimeter) bytes per round, never a
     whole-lattice gather. (A global ``jnp.roll`` on the shard_map output
     miscompiles under jit on jax 0.4.x — values get summed across the
     device axis — so the roll MUST stay inside the shard_map region; see
     tests/test_sharded_engine.py.)
  2. **local update**: every device regenerates the per-tile Philox
     proposal streams for exactly the tiles it owns
     (``rng.tile_stream_batch`` keyed by global tile id) and runs the same
     per-tile sequential sweeps as the single-device engine. Proposals are
     restricted to tile interiors and device blocks are unions of tiles,
     so no device ever writes another device's cells — communication-free
     by construction, no atomics.
  3. the shift is accumulated, not rolled back (densities are
     translation-invariant; same policy as the sublattice engine).

Because the streams are keyed by global tile id, a sharded run is
**bit-identical to the single-device sublattice engine for ANY shard
layout** — (1,1), (2,2), (4,1), ... all produce the same trajectory. The
population counts the stasis early-exit consumes are computed on the
sharded lattice at the jit level; XLA lowers them to per-shard partial
bincounts + an all-reduce (the cross-device population reduction).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from .engines import (BuiltEngine, _tiled_setup, fused_round_inputs,
                      multi_round_inputs)
from .lattice import DIRS
from .rng import ProposalBatch, round_shift, tile_stream_batch
from .sublattice import from_tiles, tile_update, to_tiles


# ------------------------- halo-exchange primitive ------------------------ #

def halo_roll(local: jax.Array, s: jax.Array, halo: int, axis_name: str,
              axis: int, n_shards: int, reverse: bool = False) -> jax.Array:
    """Distributed torus roll by a dynamic shift, via static-size halos.

    Rolls the GLOBAL lattice by ``-s`` (or ``+s`` when ``reverse``) along
    ``axis``, operating on the local block inside a shard_map region.
    Requires ``0 <= s < halo <= local block extent``: the wrapped sliver
    then crosses exactly one shard boundary, so a single ppermute of a
    static ``halo``-sized slab suffices; the dynamic part is a local
    dynamic_slice.
    """
    extent = local.shape[axis]
    if n_shards == 1:
        return jnp.roll(local, s if reverse else -s, axis)
    if not reverse:
        # new_local[i] = old[i][s:] ++ old[i+1][:s]
        head = lax.slice_in_dim(local, 0, halo, axis=axis)
        recv = lax.ppermute(head, axis_name,
                            [(i, (i - 1) % n_shards)
                             for i in range(n_shards)])
        ext = jnp.concatenate([local, recv], axis=axis)
        return lax.dynamic_slice_in_dim(ext, s, extent, axis=axis)
    # new_local[i] = old[i-1][B-s:] ++ old[i][:B-s]
    tail = lax.slice_in_dim(local, extent - halo, extent, axis=axis)
    recv = lax.ppermute(tail, axis_name,
                        [(i, (i + 1) % n_shards) for i in range(n_shards)])
    ext = jnp.concatenate([recv, local], axis=axis)
    return lax.dynamic_slice_in_dim(ext, halo - s, extent, axis=axis)


def shard_shift2d(local: jax.Array, shift: jax.Array,
                  tile_shape: Tuple[int, int], shard_grid: Tuple[int, int],
                  row_axis: str = "rows", col_axis: str = "cols",
                  reverse: bool = False) -> jax.Array:
    """Apply (or undo) the round's 2-D torus shift inside shard_map."""
    th, tw = tile_shape
    dr, dc = shard_grid
    local = halo_roll(local, shift[0], th, row_axis, 0, dr, reverse)
    local = halo_roll(local, shift[1], tw, col_axis, 1, dc, reverse)
    return local


# ------------------------------ local round ------------------------------- #

def _local_tile_ids(block_shape: Tuple[int, int],
                    tile_shape: Tuple[int, int], gw: int,
                    row_axis: str, col_axis: str) -> jax.Array:
    """Global tile ids (raster order) of the tiles this shard owns."""
    th, tw = tile_shape
    lgh, lgw = block_shape[0] // th, block_shape[1] // tw
    ri = lax.axis_index(row_axis)
    ci = lax.axis_index(col_axis)
    rows = ri * lgh + jnp.arange(lgh, dtype=jnp.int32)
    cols = ci * lgw + jnp.arange(lgw, dtype=jnp.int32)
    return (rows[:, None] * gw + cols[None, :]).reshape(-1)


def _update_tiles(local: jax.Array, props: ProposalBatch,
                  tile_shape: Tuple[int, int], t_eps: float, t_eps_mu: float,
                  dom: jax.Array, local_kernel: str = "jnp") -> jax.Array:
    """Per-tile sequential sweeps over one device's block.

    ``local_kernel`` selects the implementation (bit-identical paths, the
    single-device `pallas` vs `sublattice` guarantee lifted into the
    shard_map region): 'jnp' runs the vmapped ``tile_update`` scan, 'pallas'
    runs the VMEM-tiled ``kernels.escg_update`` kernel on the local block —
    one Pallas program per owned tile, proposals in local raster order.
    """
    if local_kernel == "pallas":
        from ..kernels import escg_update, ops as kernel_ops  # lazy: cycles
        return escg_update.escg_tile_round(
            local, props.cell, props.dirn, props.u_act, props.u_dom,
            dom, jnp.asarray(DIRS, jnp.int32), tile_shape, t_eps, t_eps_mu,
            interpret=kernel_ops._default_interpret(None))
    th, tw = tile_shape
    tiles = to_tiles(local, th, tw)
    upd = jax.vmap(lambda t, c, d, a, u: tile_update(
        t, ProposalBatch(c, d, a, u), t_eps, t_eps_mu, dom))
    tiles = upd(tiles, props.cell, props.dirn, props.u_act, props.u_dom)
    return from_tiles(tiles, local.shape[0], local.shape[1])


# ----------------------------- engine builder ----------------------------- #

def lattice_sharding(mesh: Mesh, row_axis: str = "rows",
                     col_axis: str = "cols") -> NamedSharding:
    return NamedSharding(mesh, P(row_axis, col_axis))


def round_stream_inputs(p, key: jax.Array, th: int, tw: int):
    """Per-MCS ``(stream, shift)`` pair consumed by ``make_local_round``,
    derived from one engine key EXACTLY like the single-device engine of
    the same local-kernel family (the bit-identity contract,
    ``EngineCaps.oracle_for``):

    * ``'jnp'`` / ``'pallas'``: ``stream`` is the proposal key of the
      ``split(key)`` pair, shift keyed by the other half — the
      ``_build_tiled`` schedule (oracle: ``sublattice``);
    * ``'fused'``: ``stream`` is the (2,) uint32 Philox seed words and the
      shift comes from ``fold_in(key, 1)`` — the ``pallas_fused``
      schedule (``engines.fused_round_inputs``).
    """
    if p.local_kernel == "fused":
        return fused_round_inputs(key, th, tw)
    kp, ks = jax.random.split(key)
    return kp, round_shift(ks, th, tw)


def make_local_round(p, dom, shard_grid: Tuple[int, int],
                     row_axis: str = "rows", col_axis: str = "cols"):
    """``local_round(gl, stream, shift)`` — one device-block's share of a
    round: halo shift, regenerate the owned tiles' streams, sweep.
    ``stream`` is the per-MCS proposal source from ``round_stream_inputs``
    (a PRNG key for the jnp/pallas sweeps, raw Philox seed words for the
    fused kernel).

    This is THE per-block computation both the ``sharded`` and the
    composed ``sharded_pod`` builders run inside their shard_map regions
    (sharded_pod vmaps it over its local trial slice); the cross-engine
    bit-identity contract depends on there being exactly one copy.

    ``local_kernel='fused'`` derives proposals IN-KERNEL from Philox
    counters keyed by global tile identity (the shard's tile offset +
    the global tile-grid width fold the counter): zero proposal arrays
    touch HBM inside the shard_map region, and the trajectory is
    bit-identical to the single-device ``pallas_fused`` engine for every
    mesh factorization (DESIGN.md §6).
    """
    t_eps, t_eps_mu = p.action_thresholds()
    th, tw, _, k_per, interior = _tiled_setup(p)
    gw = p.length // tw
    dr, dc = shard_grid
    # NOTE: jnp constants (dom, DIRS) are created inside the returned
    # closures, not here — this factory may run lazily under an outer jit
    # trace (the k_mcs shard_map cache), and a constant captured from one
    # trace leaks into the next (UnexpectedTracerError).

    if p.local_kernel == "fused":
        from ..kernels import escg_update_fused, ops as kernel_ops  # lazy
        interp = kernel_ops._default_interpret(None)

        def local_round(gl, seed, shift):
            gl = shard_shift2d(gl, shift, (th, tw), (dr, dc), row_axis,
                               col_axis)
            lgh, lgw = gl.shape[0] // th, gl.shape[1] // tw
            off = jnp.stack([lax.axis_index(row_axis) * lgh,
                             lax.axis_index(col_axis) * lgw])
            return escg_update_fused.escg_tile_round_fused(
                gl, seed, jnp.uint32(0), jnp.asarray(dom, jnp.float32),
                jnp.asarray(DIRS, jnp.int32), (th, tw), k_per,
                t_eps, t_eps_mu, p.neighbourhood, interpret=interp,
                tile_offset=off, grid_tiles_w=gw)
        return local_round

    def local_round(gl, kp, shift):
        gl = shard_shift2d(gl, shift, (th, tw), (dr, dc), row_axis, col_axis)
        tids = _local_tile_ids(gl.shape, (th, tw), gw, row_axis, col_axis)
        props = tile_stream_batch(kp, tids, k_per, interior, p.neighbourhood)
        return _update_tiles(gl, props, (th, tw), t_eps, t_eps_mu,
                             jnp.asarray(dom, jnp.float32),
                             local_kernel=p.local_kernel)
    return local_round


def make_local_multi_round(p, dom, shard_grid: Tuple[int, int],
                           k_steps: int, row_axis: str = "rows",
                           col_axis: str = "cols"):
    """``local_multi(gl, seeds (K, 2), shifts (K, 2)) -> (gl, counts)``
    — K fused MCS of one device-block inside the shard_map region, with
    GLOBAL per-step species counts (K, species + 1) banked alongside (the
    per-MCS density stream the drivers need for stasis detection).

    Two shapes, one contract (bit-identical to K ``local_round`` calls):

    * ``shard_grid == (1, 1)`` (every pod slice of sharded_pod, and
      sharded on one device): the whole lattice is block-resident, so the
      TRUE megakernel runs — K shift/sweep/count cycles in ONE
      ``pallas_call``, in-kernel torus roll, zero HBM round-trips between
      steps. Counts come out of the kernel already global.
    * multi-shard: the halo exchange is a cross-device collective that
      cannot live inside a ``pallas_call``, so K single-round kernels run
      back-to-back inside ONE shard_map region (launch overhead still
      amortized K× at the jit level); per-shard partial counts are
      ``psum``med into global ones.
    """
    t_eps, t_eps_mu = p.action_thresholds()
    th, tw, _, k_per, _ = _tiled_setup(p)
    gw = p.length // tw
    dr, dc = shard_grid
    from ..kernels import escg_update_fused, ops as kernel_ops  # lazy
    escg_update_fused.check_counter_capacity(
        (p.height // th) * (p.length // tw), k_per)
    interp = kernel_ops._default_interpret(None)
    n_counts = p.species + 1
    # trace safety: this factory runs lazily under the drivers' jitted
    # chunks (the per-k_steps shard_map cache), so jnp constants must be
    # created inside local_multi — see make_local_round

    if dr == dc == 1:
        def local_multi(gl, seeds, shifts):
            return escg_update_fused.escg_tile_rounds_fused(
                gl, seeds, shifts, jnp.asarray(dom, jnp.float32),
                jnp.asarray(DIRS, jnp.int32), (th, tw), k_per, t_eps,
                t_eps_mu, p.species, p.neighbourhood, interpret=interp,
                grid_tiles_w=gw)
        return local_multi

    single = make_local_round(p, dom, shard_grid, row_axis, col_axis)

    def local_multi(gl, seeds, shifts):
        counts = []
        for t in range(k_steps):        # static: K kernels, one region
            gl = single(gl, seeds[t], shifts[t])
            gi = gl.astype(jnp.int32)
            counts.append(jnp.stack([jnp.sum((gi == s).astype(jnp.int32))
                                     for s in range(n_counts)]))
        cnts = lax.psum(jnp.stack(counts), (row_axis, col_axis))
        return gl, cnts
    return local_multi


def build_engine(params, dom: jax.Array,
                 mesh: Optional[Mesh] = None,
                 row_axis: str = "rows",
                 col_axis: str = "cols") -> BuiltEngine:
    """Registry builder for engine='sharded'.

    ``mesh`` defaults to a lattice mesh over all local devices, shaped by
    ``params.shard_grid`` (auto-factored when None; see
    parallel.sharding.lattice_mesh).
    """
    from ..parallel.sharding import lattice_mesh  # lazy: parallel -> models

    p = params.validate()
    # same bookkeeping as the single-device tiled engines — the bit-identity
    # guarantee depends on k_per/interior matching exactly
    th, tw, n_tiles, k_per, _ = _tiled_setup(p)

    if mesh is None:
        mesh = lattice_mesh(p.shard_grid, p.height, p.length, th, tw,
                            row_axis=row_axis, col_axis=col_axis)
    dr, dc = mesh.shape[row_axis], mesh.shape[col_axis]
    if (p.height // dr) % th or (p.length // dc) % tw:
        raise ValueError(
            f"device blocks ({p.height // dr}x{p.length // dc}) must be "
            f"unions of {th}x{tw} tiles")

    grid_spec = P(row_axis, col_axis)
    local_round = make_local_round(p, dom, (dr, dc), row_axis, col_axis)

    round_fn = shard_map(local_round, mesh=mesh,
                         in_specs=(grid_spec, P(), P()),
                         out_specs=grid_spec, check_rep=False)

    def one_mcs(grid, key):
        stream, shift = round_stream_inputs(p, key, th, tw)
        grid = round_fn(grid, stream, shift)
        attempts = jnp.int32(n_tiles * k_per)
        return grid, attempts, attempts

    multi_mcs = None
    if p.local_kernel == "fused":
        # k_mcs megakernel path: one shard_map region per K-step group,
        # cached per distinct K (the driver only uses K and the remainder)
        multi_fns = {}

        def _multi_fn(k_steps: int):
            if k_steps not in multi_fns:
                local_multi = make_local_multi_round(
                    p, dom, (dr, dc), k_steps, row_axis, col_axis)
                multi_fns[k_steps] = shard_map(
                    local_multi, mesh=mesh,
                    in_specs=(grid_spec, P(), P()),
                    out_specs=(grid_spec, P()), check_rep=False)
            return multi_fns[k_steps]

        def multi_mcs(grid, key, k_steps):
            key, seeds, shifts = multi_round_inputs(key, th, tw, k_steps)
            grid, counts = _multi_fn(k_steps)(grid, seeds, shifts)
            attempts = jnp.int32(k_steps * n_tiles * k_per)
            return grid, key, counts, attempts, attempts

    return BuiltEngine(one_mcs, grid_sharding=lattice_sharding(
        mesh, row_axis, col_axis), multi_mcs=multi_mcs)


# --------------------- explicit-proposal round (tests) -------------------- #

def sharded_run_round(grid: jax.Array, props: ProposalBatch,
                      shift: jax.Array, tile_shape: Tuple[int, int],
                      t_eps: float, t_eps_mu: float, dom: jax.Array,
                      mesh: Mesh, row_axis: str = "data",
                      col_axis: str = "model",
                      roll_back: bool = True,
                      local_kernel: str = "jnp") -> jax.Array:
    """One shifted-window round with externally supplied proposals in
    global raster tile order, shape (T, K). Bit-identical to
    ``sublattice.run_round`` on the same inputs; jit-safe (all rolls happen
    inside the shard_map region)."""
    h, w = grid.shape
    th, tw = tile_shape
    gh, gw = h // th, w // tw
    dr = mesh.shape[row_axis]
    dc = mesh.shape[col_axis]
    if (h // dr) % th or (w // dc) % tw:
        raise ValueError("device blocks must be unions of tiles")

    grid_spec = P(row_axis, col_axis)
    prop_spec = P(row_axis, col_axis, None)

    def reshape_props(a):
        return a.reshape(gh, gw, -1)

    def local_round(gl, sh, cell, dirn, ua, ud):
        gl = shard_shift2d(gl, sh, (th, tw), (dr, dc), row_axis, col_axis)
        k = cell.shape[-1]
        props_l = ProposalBatch(cell.reshape(-1, k), dirn.reshape(-1, k),
                                ua.reshape(-1, k), ud.reshape(-1, k))
        gl = _update_tiles(gl, props_l, (th, tw), t_eps, t_eps_mu, dom,
                           local_kernel=local_kernel)
        if roll_back:
            gl = shard_shift2d(gl, sh, (th, tw), (dr, dc), row_axis,
                               col_axis, reverse=True)
        return gl

    update = shard_map(
        local_round, mesh=mesh,
        in_specs=(grid_spec, P(), prop_spec, prop_spec, prop_spec,
                  prop_spec),
        out_specs=grid_spec, check_rep=False)

    return update(grid, shift, reshape_props(props.cell),
                  reshape_props(props.dirn), reshape_props(props.u_act),
                  reshape_props(props.u_dom))


def make_sharded_simulation(params, dom, mesh: Mesh,
                            row_axis: str = "data",
                            col_axis: str = "model",
                            roll_back: bool = True):
    """Returns (grid_sharding, jitted one_mcs(grid, key) -> grid) on an
    explicit mesh — the notebook/driver-facing wrapper.

    Unlike the registered engine (which accumulates the random window
    shift; densities are translation-invariant), this wrapper rolls the
    lattice back every MCS by default, so snapshots and spatial analyses
    of the returned grid stay in the fixed reference frame. Pass
    ``roll_back=False`` for the cheaper drifting-frame variant.
    """
    p = params.validate()
    if p.engine not in ("sublattice", "pallas", "sharded"):
        raise ValueError("sharded ESCG uses a tiled engine")
    t_eps, t_eps_mu = p.action_thresholds()
    th, tw, n_tiles, k_per, interior = _tiled_setup(p)
    dom_j = jnp.asarray(dom, jnp.float32)
    tile_ids = jnp.arange(n_tiles, dtype=jnp.int32)

    @jax.jit
    def one_mcs(grid, key):
        kp, ks = jax.random.split(key)
        props = tile_stream_batch(kp, tile_ids, k_per, interior,
                                  p.neighbourhood)
        shift = round_shift(ks, th, tw)
        return sharded_run_round(grid, props, shift, (th, tw), t_eps,
                                 t_eps_mu, dom_j, mesh, row_axis, col_axis,
                                 roll_back=roll_back)

    return lattice_sharding(mesh, row_axis, col_axis), one_mcs
