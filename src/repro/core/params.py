"""Simulation parameters — CLI-parity with the paper's Tables 3.1 and 3.2.

The paper exposes a single configurable simulator; we mirror every flag
(``--length``, ``--height``, ``--mcs``, ``--neighbourhood``, ``--mobility``,
``--species``, ``--flux``, ``--empty``, ``--save``, ``--dominance``,
``--resume``, ``--numRandoms``, ``--maxStep``) plus engine-selection knobs
introduced by the TPU adaptation (see DESIGN.md §2).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import re
from dataclasses import dataclass
from typing import Optional, Tuple

from .engines import engine_names, validate_params as _validate_engine


def __getattr__(name: str):
    # Back-compat `params.ENGINES` alias (DESIGN.md §2). A module-level
    # constant would snapshot engine_names() at import time and go stale
    # after late @register calls (notebooks, tests, plugins); deferring to
    # the registry through the module __getattr__ keeps it live.
    if name == "ENGINES":
        return engine_names()
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


@dataclass(frozen=True)
class EscgParams:
    # ---- paper Table 3.1 ----
    length: int = 200              # lattice width  W
    height: int = 200              # lattice height H
    mcs: int = 100_000             # Monte Carlo step limit
    neighbourhood: int = 4         # 4 = von Neumann, 8 = Moore
    print_frequency: int = 200     # density print interval (MCS)
    mobility: float = 3e-5         # M: typical area explored per unit time
    species: int = 3
    flux: bool = True              # periodic (wrap) boundary; False = reflect
    empty: float = 0.0             # initial empty-cell probability
    save: bool = False             # export snapshots/state
    # ---- paper Table 3.2 (GPU extensions) ----
    resume: bool = False
    num_randoms: int = 0           # proposals per round; 0 -> N (one MCS/round)
    max_step: bool = False         # multiple MCS per round (maxStep mode)
    # ---- action rates (paper §3.1.1) ----
    mu: float = 1.0                # interaction
    sigma: float = 1.0             # reproduction
    epsilon: Optional[float] = None  # migration; default 2*M*N (paper)
    # ---- TPU adaptation knobs ----
    engine: str = "batched"        # any registered engine (engines.py)
    cell_dtype: str = "int32"      # int8 quarters lattice HBM traffic
    tile: Tuple[int, int] = (8, 32)   # sublattice tile (th, tw)
    seed: int = 0
    chunk_mcs: int = 100           # MCS per jitted chunk (device-resident loop)
    out_dir: str = "escg_out"
    # sharded engine: (rows, cols) device grid; None = auto-factor all
    # local devices (parallel.sharding.auto_shard_grid)
    shard_grid: Optional[Tuple[int, int]] = None
    # sharded_pod engine: (pod, rows, cols) composed device mesh — the
    # trial axis shards over 'pod' while each trial's lattice is
    # domain-decomposed over ('rows','cols'); None = all local devices on
    # the pod axis (DESIGN.md §6). Which layouts are legal is decided by
    # the engine's EngineCaps.mesh_axes, not by the drivers.
    mesh_shape: Optional[Tuple[int, int, int]] = None
    # tile sweep implementation inside the sharded engines' shard_map
    # region: 'jnp' (vmapped lax.scan sweeps), 'pallas' (the VMEM-tiled
    # kernels.escg_update path, bit-identical to 'jnp'), or 'fused'
    # (in-kernel Philox proposal derivation keyed by global tile identity
    # — zero proposal HBM traffic, bit-identical to engine='pallas_fused')
    local_kernel: str = "jnp"
    # Monte-Carlo steps per kernel launch (the multi-MCS megakernel,
    # DESIGN.md §6): k_mcs > 1 runs K steps grid-resident per pallas_call,
    # amortizing launch overhead and HBM round-trips K×. Fused-Philox
    # family only (engine pallas_fused, or sharded/sharded_pod with
    # local_kernel='fused'); bit-identical to k_mcs=1 by construction.
    k_mcs: int = 1
    # streaming observables evaluated inside the jitted engine step and
    # ring-buffered in device memory (DESIGN.md §11); () = off (legacy
    # per-chunk counts transfer). Names resolve through the observable
    # registry (core/observables.py); scenario-first driver calls fill
    # this from ScenarioCaps.observables.
    observables: Tuple[str, ...] = ()
    # ring-buffer row capacity; 0 = auto (one chunk of rows, lossless).
    # The trial driver tolerates smaller capacities (lossy wraparound);
    # simulate requires capacity >= chunk_mcs (its stasis accounting
    # reads the flushed rows).
    obs_capacity: int = 0

    # ------------------------------------------------------------------ #
    @property
    def n_cells(self) -> int:
        return self.length * self.height

    @property
    def eps(self) -> float:
        if self.epsilon is not None:
            return float(self.epsilon)
        return 2.0 * self.mobility * self.n_cells

    def action_thresholds(self) -> Tuple[float, float]:
        """Normalized cumulative thresholds (t_eps, t_eps_mu) on u ~ U[0,1).

        u <  t_eps          -> migration
        u <  t_eps_mu       -> interaction
        else                -> reproduction
        (paper Algorithm 3.2 ordering)
        """
        total = self.mu + self.sigma + self.eps
        if total <= 0:
            raise ValueError("mu + sigma + epsilon must be positive")
        return self.eps / total, (self.eps + self.mu) / total

    @property
    def proposals_per_round(self) -> int:
        n = self.num_randoms if self.num_randoms > 0 else self.n_cells
        if not self.max_step:
            n = min(n, self.n_cells)
        # paper: numRandoms = (numRandoms / N) * N  (align with whole MCS)
        n = max(self.n_cells, (n // self.n_cells) * self.n_cells)
        return n

    @property
    def mcs_per_round(self) -> int:
        return self.proposals_per_round // self.n_cells

    def validate(self) -> "EscgParams":
        if self.neighbourhood not in (4, 8):
            raise ValueError("neighbourhood must be 4 or 8")
        if self.species < 1:
            raise ValueError("species >= 1")
        if not (0.0 <= self.empty <= 1.0):
            raise ValueError("empty in [0,1]")
        if self.length < 3 or self.height < 3:
            raise ValueError("lattice must be at least 3x3")
        if self.cell_dtype not in ("int8", "int16", "int32"):
            raise ValueError("cell_dtype must be int8/int16/int32")
        if self.cell_dtype == "int8" and self.species > 127:
            raise ValueError("int8 lattice supports <= 127 species")
        # engine existence + capability checks (flux, tile, devices) live
        # with the registry so new engines carry their own constraints
        _validate_engine(self)
        return self

    # ------------------------------ io -------------------------------- #
    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self))

    @staticmethod
    def from_json(s: str) -> "EscgParams":
        d = json.loads(s)
        d["tile"] = tuple(d["tile"])
        if d.get("shard_grid") is not None:
            d["shard_grid"] = tuple(d["shard_grid"])
        if d.get("mesh_shape") is not None:
            d["mesh_shape"] = tuple(d["mesh_shape"])
        if d.get("observables") is not None:
            d["observables"] = tuple(d["observables"])
        return EscgParams(**d)

    def replace(self, **kw) -> "EscgParams":
        return dataclasses.replace(self, **kw)

    # -------------------- scenario-layer facade ----------------------- #
    @classmethod
    def from_scenario(cls, scenario, engine_config=None,
                      run_config=None) -> "EscgParams":
        """Compose a ``Scenario`` (+ optional ``EngineConfig`` /
        ``RunConfig``) into the legacy flat params — the back-compat
        facade over the scenario layer (DESIGN.md §10). Bit-identical to
        hand-building the same ``EscgParams``."""
        from .scenarios import compose  # lazy: scenarios imports us
        return compose(scenario, engine_config, run_config)

    def to_scenario(self, name: str = ""):
        """Decompose into ``(Scenario, EngineConfig, RunConfig)``;
        ``EscgParams.from_scenario(*p.to_scenario()) == p``."""
        from .scenarios import decompose  # lazy: scenarios imports us
        return decompose(self, name=name)


def _mesh_shape(s: str) -> Tuple[int, int, int]:
    """Parse ``--meshShape P,R,C`` (also accepts 'PxRxC')."""
    parts = [x for x in re.split(r"[,x]", s) if x]
    if len(parts) != 3:
        raise argparse.ArgumentTypeError(
            f"meshShape must be P,R,C (three ints), got {s!r}")
    return tuple(int(x) for x in parts)


def add_cli_args(p: argparse.ArgumentParser) -> None:
    b = lambda s: s.lower() in ("1", "true", "yes")  # noqa: E731
    p.add_argument("--length", type=int, default=200)
    p.add_argument("--height", type=int, default=200)
    p.add_argument("--mcs", type=int, default=100_000)
    p.add_argument("--neighbourhood", type=int, default=4, choices=(4, 8))
    p.add_argument("--printFrequency", dest="print_frequency", type=int,
                   default=200)
    p.add_argument("--mobility", type=float, default=3e-5)
    p.add_argument("--species", type=int, default=3)
    p.add_argument("--flux", type=b, default=True)
    p.add_argument("--empty", type=float, default=0.0)
    p.add_argument("--save", type=b, default=False)
    p.add_argument("--dominance", type=str, default="",
                   help="path to dominance .csv (paper --dominance)")
    p.add_argument("--resume", type=b, default=False)
    p.add_argument("--numRandoms", dest="num_randoms", type=int, default=0)
    p.add_argument("--maxStep", dest="max_step", type=b, default=False)
    p.add_argument("--mu", type=float, default=1.0)
    p.add_argument("--sigma", type=float, default=1.0)
    p.add_argument("--epsilon", type=float, default=None)
    p.add_argument("--engine", type=str, default="batched",
                   choices=engine_names())
    p.add_argument("--cellDtype", dest="cell_dtype", type=str,
                   default="int32", choices=("int8", "int16", "int32"))
    p.add_argument("--tile", type=int, nargs=2, default=(8, 32))
    p.add_argument("--shardGrid", dest="shard_grid", type=int, nargs=2,
                   default=None,
                   help="(rows, cols) device grid for engine=sharded; "
                        "omit to auto-factor all local devices")
    p.add_argument("--meshShape", dest="mesh_shape", type=_mesh_shape,
                   default=None, metavar="P,R,C",
                   help="composed (pod, rows, cols) device mesh for "
                        "engine=sharded_pod: --trials shard over the pod "
                        "axis, each lattice over (rows, cols); omit to put "
                        "all local devices on the pod axis")
    p.add_argument("--localKernel", dest="local_kernel", type=str,
                   default="jnp", choices=("jnp", "pallas", "fused"),
                   help="tile-sweep implementation inside the sharded "
                        "engines' shard_map region: jnp and pallas are "
                        "bit-identical to each other; fused derives "
                        "proposals in-kernel from Philox counters (zero "
                        "proposal HBM traffic, bit-identical to "
                        "--engine pallas_fused)")
    p.add_argument("--kMcs", dest="k_mcs", type=int, default=1,
                   help="Monte-Carlo steps fused into one kernel launch "
                        "(the multi-MCS megakernel; fused-Philox engines "
                        "only, bit-identical to --kMcs 1)")
    p.add_argument("--observables", type=str, default=None,
                   help="comma-separated streaming observables computed "
                        "on-device and ring-buffered (DESIGN.md §11), "
                        "e.g. 'densities,interface_length'; 'none' "
                        "disables; default: off (with --scenario, the "
                        "preset's ScenarioCaps.observables)")
    p.add_argument("--obsCapacity", dest="obs_capacity", type=int,
                   default=0,
                   help="observable ring-buffer capacity in rows; 0 = "
                        "auto (one chunk, lossless)")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--chunkMcs", dest="chunk_mcs", type=int, default=100)
    p.add_argument("--outDir", dest="out_dir", type=str, default="escg_out")


def parse_observables(s: Optional[str]) -> Optional[Tuple[str, ...]]:
    """``--observables`` string -> tuple ('none'/'' -> (), None -> None:
    flag not given, defer to the scenario/default)."""
    if s is None:
        return None
    s = s.strip()
    if not s or s.lower() == "none":
        return ()
    return tuple(x.strip() for x in s.split(",") if x.strip())


def params_from_args(args: argparse.Namespace) -> EscgParams:
    fields = {f.name for f in dataclasses.fields(EscgParams)}
    kw = {k: v for k, v in vars(args).items() if k in fields and v is not None}
    if "tile" in kw:
        kw["tile"] = tuple(kw["tile"])
    if "observables" in kw:
        kw["observables"] = parse_observables(kw["observables"]) or ()
    if kw.get("shard_grid") is not None:
        kw["shard_grid"] = tuple(kw["shard_grid"])
    if kw.get("mesh_shape") is not None:
        kw["mesh_shape"] = tuple(kw["mesh_shape"])
    return EscgParams(**kw).validate()
