"""Elementary-step semantics (paper Algorithm 3.2) as a pure pair update.

This module is the single source of truth for the game rules. Every engine
(sequential reference, batched maxStep port, sublattice engine, Pallas kernel)
applies exactly this function to the (cell, neighbour) pair, so engine
equivalence reduces to scheduling equivalence.

Given cell species ``s``, neighbour species ``n``, an action draw
``u_act ~ U[0,1)`` and a dominance draw ``u_dom ~ U[0,1)``:

    if s == n:                      no-op            (paper: skip same species)
    elif u_act < t_eps:             migration        (swap)
    elif u_act < t_eps_mu:          interaction      (probabilistic dominance)
    else:                           reproduction     (fill the empty site)

Interaction uses the padded dominance matrix D (row/col 0 = empty = all
zeros): with p1 = D[s, n], p2 = D[n, s],
    u_dom <  p1        -> neighbour dies
    u_dom <  p1 + p2   -> cell dies
which reproduces the paper's deterministic ``dominates()`` branch when
p ∈ {0,1} and Park et al.'s probabilistic rates otherwise. Emptiness guards
(interaction needs both non-empty; reproduction needs exactly one empty) are
implied by the zero padding and the s != n precondition.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def apply_pair(s: jax.Array, n: jax.Array, u_act: jax.Array,
               u_dom: jax.Array, t_eps: float, t_eps_mu: float,
               dom: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Vectorized pure pair update. All args broadcastable; returns the new
    pair in the input cell dtype (int8 lattices supported)."""
    cell_dt = s.dtype
    s = s.astype(jnp.int32)
    n = n.astype(jnp.int32)
    same = s == n

    migrate = u_act < t_eps
    interact = (u_act >= t_eps) & (u_act < t_eps_mu)
    reproduce = u_act >= t_eps_mu

    p1 = dom[s, n]
    p2 = dom[n, s]
    kill_n = interact & (u_dom < p1)
    kill_s = interact & ~kill_n & (u_dom < p1 + p2)

    rep_to_n = reproduce & (n == 0)     # s != n ensures s != 0 here
    rep_to_s = reproduce & (s == 0)

    zero = jnp.zeros_like(s)
    new_s = jnp.where(migrate, n,
            jnp.where(kill_s, zero,
            jnp.where(rep_to_s, n, s)))
    new_n = jnp.where(migrate, s,
            jnp.where(kill_n, zero,
            jnp.where(rep_to_n, s, n)))

    new_s = jnp.where(same, s, new_s)
    new_n = jnp.where(same, n, new_n)
    return new_s.astype(cell_dt), new_n.astype(cell_dt)


def apply_pair_reference(s: int, n: int, u_act: float, u_dom: float,
                         t_eps: float, t_eps_mu: float, dom) -> Tuple[int, int]:
    """Plain-Python transliteration of paper Algorithm 3.2 (test oracle)."""
    if s == n:
        return s, n
    if u_act < t_eps:                       # migration
        return n, s
    if u_act < t_eps_mu:                    # interaction
        p1 = float(dom[s, n])
        p2 = float(dom[n, s])
        if u_dom < p1:
            return s, 0                     # neighbour dies
        if u_dom < p1 + p2:
            return 0, n                     # self dies
        return s, n
    # reproduction
    if n == 0:
        return s, s
    if s == 0:
        return n, n
    return s, n
