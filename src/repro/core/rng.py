"""Batched pseudo-random proposal streams (paper T1: §2.6, §3.2.1).

The paper's key PRNG insight — generate large batches of (cell, direction,
action) draws in parallel on-device and consume them by indexed lookup — maps
directly onto counter-based PRNGs: generation is embarrassingly parallel and
needs no per-thread Mersenne-Twister state, seed hashing, or burn-in (the
paper's Fig 3.4 pathology cannot occur by construction; see DESIGN.md §3).

Default backend: JAX threefry. A Pallas Philox-4x32 kernel
(``repro.kernels.philox``) provides the explicitly-tiled variant used in the
PRNG benchmark (paper Fig 4.1).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class ProposalBatch(NamedTuple):
    """One round of elementary-step proposals (device-resident)."""
    cell: jax.Array    # int32[B]  flat cell index in [0, N)
    dirn: jax.Array    # int32[B]  direction id in [0, nbhd)
    u_act: jax.Array   # float32[B] action draw in [0, 1)
    u_dom: jax.Array   # float32[B] dominance draw in [0, 1)


def proposal_batch(key: jax.Array, n_proposals: int, n_cells: int,
                   neighbourhood: int) -> ProposalBatch:
    """Draw one batch of proposals (the paper's refreshRandomNumbers)."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return ProposalBatch(
        cell=jax.random.randint(k1, (n_proposals,), 0, n_cells,
                                dtype=jnp.int32),
        dirn=jax.random.randint(k2, (n_proposals,), 0, neighbourhood,
                                dtype=jnp.int32),
        u_act=jax.random.uniform(k3, (n_proposals,), dtype=jnp.float32),
        u_dom=jax.random.uniform(k4, (n_proposals,), dtype=jnp.float32),
    )


def tile_proposal_batch(key: jax.Array, n_tiles: int, k_per_tile: int,
                        interior: int, neighbourhood: int) -> ProposalBatch:
    """Proposals for the sublattice engine: per-tile interior cell ids.

    ``cell`` here is an index into the (th-2)x(tw-2) interior window of each
    tile (the kernel adds the +1 inset); shape (n_tiles, K).
    """
    k1, k2, k3, k4 = jax.random.split(key, 4)
    shape = (n_tiles, k_per_tile)
    return ProposalBatch(
        cell=jax.random.randint(k1, shape, 0, interior, dtype=jnp.int32),
        dirn=jax.random.randint(k2, shape, 0, neighbourhood, dtype=jnp.int32),
        u_act=jax.random.uniform(k3, shape, dtype=jnp.float32),
        u_dom=jax.random.uniform(k4, shape, dtype=jnp.float32),
    )


def tile_stream_batch(key: jax.Array, tile_ids: jax.Array, k_per_tile: int,
                      interior: int, neighbourhood: int) -> ProposalBatch:
    """Per-tile counter-based proposal streams: tile ``t``'s draws depend
    only on ``(key, global tile id)``, never on how tiles are grouped onto
    devices. This is what makes the sharded engine bit-identical to the
    single-device sublattice engine for ANY shard layout — each shard
    regenerates exactly the streams of the tiles it owns (the sPEGG /
    counter-based-PRNG idiom; no cross-device RNG state).

    ``tile_ids``: int array of global tile ids; returns (len(tile_ids), K)
    arrays in the same order.
    """
    def one(tid):
        k1, k2, k3, k4 = jax.random.split(jax.random.fold_in(key, tid), 4)
        return ProposalBatch(
            cell=jax.random.randint(k1, (k_per_tile,), 0, interior,
                                    dtype=jnp.int32),
            dirn=jax.random.randint(k2, (k_per_tile,), 0, neighbourhood,
                                    dtype=jnp.int32),
            u_act=jax.random.uniform(k3, (k_per_tile,), dtype=jnp.float32),
            u_dom=jax.random.uniform(k4, (k_per_tile,), dtype=jnp.float32),
        )
    return jax.vmap(one)(jnp.asarray(tile_ids, jnp.int32))


def round_shift(key: jax.Array, th: int, tw: int) -> jax.Array:
    """Uniform torus shift (dy, dx) in [0,th) x [0,tw) for one sublattice
    round (Shim-Amar randomized sublattice origin)."""
    return jax.random.randint(key, (2,), 0, jnp.array([th, tw]),
                              dtype=jnp.int32)
