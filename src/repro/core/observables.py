"""On-device observable pipelines (DESIGN.md §11).

The paper offloads per-MCS density counting to the GPU (§3.2.2,
densities.metal) because host-side statistics dominate long runs; the
wafer-scale agent-evolution work (PAPERS.md) generalizes the lesson:
instrumentation must be computed where the state lives and flushed
asynchronously. This module is that mechanism — a first-class registry of
*streaming observables* (mirroring ``engines.py`` / ``scenarios.py``)
that the chunked drivers evaluate INSIDE the jitted engine step and bank
into a device-resident ring buffer; the host only ever sees the flushed
rows at chunk boundaries.

Registry contract (``@register_observable``):

* ``width(params) -> int`` — static row-slice width of the observable;
* ``compute(grid, counts, params) -> (width,) float32`` — pure function
  of the lattice and the already-banked per-MCS species counts. It MUST
  NOT consume PRNG state or mutate anything: observables-on vs
  observables-off trajectories are bit-identical *by construction*, and
  the equivalence suite pins it (tests/test_observables.py);
* ``post(rows, params) -> np.ndarray`` — host-side finalization of the
  flushed raw rows (e.g. raw species counts -> densities). Device rows
  carry raw integer statistics in float32 (exact below 2**24), so the
  host can reconstruct counts losslessly at the lattice sizes tested;
* ``from_counts`` — True when the observable is a pure function of the
  banked counts. Under the k_mcs megakernel intermediate grids never
  leave the kernel, so count-derived observables keep per-MCS cadence
  (read from the banked (K, S+1) counts) while grid-derived observables
  are *lag-held*: rows within a K-step launch group repeat the value
  sampled at the previous group boundary (documented flush semantics,
  DESIGN.md §11).

Ring-buffer layout: a ``(capacity, obs_width)`` float32 array (trial
batches: ``(capacity, n_pad, obs_width)``) advanced by
``lax.dynamic_update_slice`` at slot ``pos % capacity`` with a monotonic
``pos`` counter. The host flush (:func:`ring_flush`) unrolls
``[start, stop)`` modulo capacity and drops the oldest rows when a chunk
outran the capacity — wraparound is lossy-by-design for the trial
driver's statistics stream and forbidden (capacity >= chunk) for
``simulate``, whose stasis accounting reads the flushed rows.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "ObservableSpec", "register_observable", "observable_names",
    "observable_specs", "get_observable", "resolve", "ObsPipeline",
    "build_pipeline", "build_observe", "ring_init", "ring_push",
    "ring_flush", "ring_capacity",
]


# ------------------------------- registry ---------------------------------- #

@dataclass(frozen=True)
class ObservableSpec:
    """One registered streaming observable (see module docstring)."""
    name: str
    width: Callable[..., int] = field(repr=False, default=None)
    compute: Callable[..., jax.Array] = field(repr=False, default=None)
    post: Callable[..., np.ndarray] = field(repr=False, default=None)
    from_counts: bool = False   # derivable from the banked per-MCS counts
    description: str = ""


_REGISTRY: Dict[str, ObservableSpec] = {}


def register_observable(name: str, *, width: Callable[..., int],
                        from_counts: bool = False,
                        post: Optional[Callable] = None,
                        description: str = ""):
    """Decorator: register ``compute(grid, counts, params) -> (width,)
    float32`` under ``name``. Re-registration replaces (same contract as
    the engine and scenario registries)."""
    def deco(compute_fn):
        _REGISTRY[name] = ObservableSpec(
            name=name, width=width, compute=compute_fn,
            post=post or (lambda rows, p: rows),
            from_counts=from_counts, description=description)
        return compute_fn
    return deco


def observable_names() -> Tuple[str, ...]:
    return tuple(_REGISTRY)


def observable_specs() -> Tuple[ObservableSpec, ...]:
    return tuple(_REGISTRY.values())


def get_observable(name: str) -> ObservableSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown observable {name!r}; registered: {observable_names()}"
        ) from None


def resolve(names) -> Tuple[ObservableSpec, ...]:
    """Requested names -> specs in canonical registry order, deduplicated.
    Unknown names raise (the same error params validation surfaces)."""
    want = set()
    for n in names:
        get_observable(n)
        want.add(n)
    return tuple(s for s in _REGISTRY.values() if s.name in want)


# ------------------------------- pipeline ---------------------------------- #

@dataclass(frozen=True)
class ObsPipeline:
    """A resolved observable set for one params: row layout + kernels.

    The row is the concatenation of every spec's slice in registry order;
    ``densities`` is always present and always first (the drivers
    reconstruct per-MCS species counts — stasis detection, hooks, the
    density history — from its raw-count columns, so the flushed ring
    fully replaces the legacy per-chunk counts transfer)."""
    specs: Tuple[ObservableSpec, ...]
    widths: Tuple[int, ...]
    offsets: Tuple[int, ...]
    width: int
    _params: object = field(repr=False, default=None)

    # ------------------------- device side ----------------------------- #
    def row(self, grid: jax.Array, counts: jax.Array) -> jax.Array:
        """Full (width,) float32 row — per-MCS cadence path."""
        p = self._params
        return jnp.concatenate(
            [s.compute(grid, counts, p).astype(jnp.float32).reshape(-1)
             for s in self.specs])

    def grid_values(self, grid: jax.Array) -> Dict[str, jax.Array]:
        """Grid-derived slices sampled at a launch-group boundary (the
        lag-hold state under k_mcs > 1); count-derived specs excluded."""
        p = self._params
        return {s.name: s.compute(grid, None, p).astype(jnp.float32)
                .reshape(-1) for s in self.specs if not s.from_counts}

    def row_held(self, counts: jax.Array,
                 held: Dict[str, jax.Array]) -> jax.Array:
        """Row for one megakernel-interior MCS: count-derived slices from
        the banked ``counts``, grid-derived slices from ``held``."""
        p = self._params
        parts = []
        for s in self.specs:
            if s.from_counts:
                parts.append(s.compute(None, counts, p)
                             .astype(jnp.float32).reshape(-1))
            else:
                parts.append(held[s.name])
        return jnp.concatenate(parts)

    # -------------------------- host side ------------------------------ #
    def counts_from_rows(self, rows: np.ndarray, species: int) -> np.ndarray:
        """Per-MCS (..., S+1) int32 species counts from flushed raw rows
        (the ``densities`` slice is leading and stores raw counts)."""
        return rows[..., : species + 1].astype(np.int32)

    def split(self, rows: np.ndarray) -> Dict[str, np.ndarray]:
        """Flushed raw rows (..., width) -> finalized per-observable
        arrays, each spec's ``post`` applied."""
        p = self._params
        out = {}
        for s, off, w in zip(self.specs, self.offsets, self.widths):
            out[s.name] = s.post(
                np.asarray(rows[..., off:off + w], np.float64), p)
        return out


def build_pipeline(p) -> ObsPipeline:
    """Pipeline for ``p.observables``; ``densities`` is implicitly
    prepended when absent (the drivers' stasis/density accounting needs
    its raw-count columns — see :class:`ObsPipeline`)."""
    names = tuple(p.observables)
    if "densities" not in names:
        names = ("densities",) + names
    specs = resolve(names)
    widths = tuple(int(s.width(p)) for s in specs)
    offsets = tuple(int(x) for x in np.cumsum((0,) + widths[:-1]))
    return ObsPipeline(specs=specs, widths=widths, offsets=offsets,
                       width=int(sum(widths)), _params=p)


def build_observe(p) -> Callable[[jax.Array, jax.Array], jax.Array]:
    """The engine-facing ``observe(grid, counts) -> (obs_width,) float32``
    hook carried by ``BuiltEngine.observe`` (validated by ``EngineCaps``
    rails). One generic jit-level implementation serves every engine
    family: on sharded lattices the reductions lower to per-shard
    partials plus all-reduces (the same mechanism as the stasis counts,
    and as ``kernels/density.py`` under shard_map with psum)."""
    pipe = build_pipeline(p)
    return pipe.row


# ------------------------------ ring buffer -------------------------------- #

def ring_init(capacity: int, row_shape: Tuple[int, ...]):
    """Device-resident ring: ``(zeros (capacity, *row_shape) f32,
    pos=0)``. ``pos`` counts every row ever pushed (monotonic); the slot
    written is ``pos % capacity``."""
    if capacity < 1:
        raise ValueError(f"ring capacity must be >= 1, got {capacity}")
    return (jnp.zeros((capacity,) + tuple(row_shape), jnp.float32),
            jnp.int32(0))


def ring_push(ring: jax.Array, pos: jax.Array, row: jax.Array):
    """Write ``row`` at slot ``pos % capacity`` via
    ``lax.dynamic_update_slice``; returns ``(ring, pos + 1)``. Trace-safe
    inside scan/fori bodies."""
    cap = ring.shape[0]
    idx = jax.lax.rem(pos, jnp.int32(cap))
    start = (idx,) + (jnp.int32(0),) * (ring.ndim - 1)
    return (jax.lax.dynamic_update_slice(ring, row[None].astype(ring.dtype),
                                         start),
            pos + jnp.int32(1))


def ring_push_many(ring: jax.Array, pos: jax.Array, rows: jax.Array):
    """Push ``rows[(t, ...)]`` in order t = 0..T-1 (T static). Used where
    rows are banked first — the megakernel's per-step counts, the trial
    batch's scanned row stack — and written to the ring afterwards."""
    def body(t, carry):
        r, q = carry
        return ring_push(r, q, rows[t])
    return jax.lax.fori_loop(0, rows.shape[0], body, (ring, pos))


def ring_flush(buf_h: np.ndarray, start: int, stop: int) -> np.ndarray:
    """Host-side unroll of rows ``[start, stop)`` (absolute push indices)
    out of a flushed ring buffer. Rows older than ``stop - capacity``
    were overwritten on device and are dropped (lossy wraparound — the
    trial driver's documented semantics; ``simulate`` sizes the ring so
    this never drops)."""
    cap = buf_h.shape[0]
    if stop < start:
        raise ValueError(f"ring_flush: stop {stop} < start {start}")
    lost = max(0, (stop - start) - cap)
    idx = np.arange(start + lost, stop, dtype=np.int64) % cap
    return buf_h[idx]


def ring_capacity(p, default_rows: int) -> int:
    """Effective ring capacity: ``params.obs_capacity`` when set, else
    ``default_rows`` (the drivers pass their per-chunk row count — a
    lossless auto default)."""
    return int(p.obs_capacity) if p.obs_capacity else int(default_rows)


# -------------------------- registered observables ------------------------- #
# Canonical registry order is row-layout order: densities first (the
# drivers depend on it — build_pipeline), then the grid-derived set.

@register_observable(
    "densities", width=lambda p: p.species + 1, from_counts=True,
    post=lambda rows, p: rows / p.n_cells,
    description="per-species population share, col 0 = empties (paper "
                "§3.2.2 densities.metal; raw counts on device, "
                "normalized on flush)")
def _obs_densities(grid, counts, p):
    # reuses the banked per-MCS counts — zero extra compute on device
    return counts.astype(jnp.float32)


@register_observable(
    "interface_length", width=lambda p: 1,
    post=lambda rows, p: rows / (2.0 * p.n_cells),
    description="fraction of unlike nearest-neighbour bonds on the torus "
                "— the domain-wall / interface length density of the RMF "
                "spiral regime")
def _obs_interface_length(grid, counts, p):
    right = jnp.roll(grid, -1, axis=1)
    down = jnp.roll(grid, -1, axis=0)
    n_unlike = (jnp.sum(grid != right) + jnp.sum(grid != down))
    return n_unlike.astype(jnp.float32).reshape(1)


@register_observable(
    "cluster_size", width=lambda p: 1,
    post=lambda rows, p: rows / (2.0 * p.n_cells),
    description="same-species occupied-bond density — a cluster-size "
                "proxy: rises toward the coordination bound as domains "
                "coarsen")
def _obs_cluster_size(grid, counts, p):
    right = jnp.roll(grid, -1, axis=1)
    down = jnp.roll(grid, -1, axis=0)
    n_like = (jnp.sum((grid == right) & (grid > 0))
              + jnp.sum((grid == down) & (grid > 0)))
    return n_like.astype(jnp.float32).reshape(1)


def _snap_shape(p) -> Tuple[int, int]:
    return min(8, p.height), min(8, p.length)


def _snap_post(rows: np.ndarray, p) -> np.ndarray:
    gh, gw = _snap_shape(p)
    return rows.reshape(rows.shape[:-1] + (gh, gw))


@register_observable(
    "snapshot", width=lambda p: _snap_shape(p)[0] * _snap_shape(p)[1],
    post=_snap_post,
    description="coarse-grained lattice snapshot: dominant species label "
                "per block of an (up to) 8x8 partition — the serving "
                "layer's progress thumbnail")
def _obs_snapshot(grid, counts, p):
    gh, gw = _snap_shape(p)
    bh, bw = p.height // gh, p.length // gw
    g = grid[: gh * bh, : gw * bw].reshape(gh, bh, gw, bw)
    labels = jax.lax.iota(jnp.int32, p.species + 1).reshape(1, 1, -1)
    blocks = g.transpose(0, 2, 1, 3).reshape(gh, gw, bh * bw)
    hist = jnp.sum(blocks[..., None] == labels[None], axis=2)
    return jnp.argmax(hist, axis=-1).astype(jnp.float32).reshape(-1)
