"""Park, Chen & Szolnoki (2023) eight-species alliance model (paper §4.3.2)
plus the mobility extension of the Cliff & Sinadjan companion paper (App. C).

Park et al.: no mobility (epsilon = 0), probabilistic dominance rates
(alpha, beta, gamma), L x L lattice, terminate after L^2 MCS, survival
statistics over many IID runs. The companion paper's contribution is a single
knob: mobility > 0, which we expose directly.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np

from .dominance import park_alliance_network
from .params import EscgParams
from .trials import run_trials


def park_params(L: int = 100, mcs: Optional[int] = None,
                mobility: float = 0.0, engine: str = "batched",
                seed: int = 0, **kw) -> EscgParams:
    """Paper/Park defaults: S=8, no empties... Park's model has no empty
    sites initially; interactions produce empties which reproduction refills.
    Terminates after L^2 MCS (paper Fig 4.9/4.10)."""
    return EscgParams(
        length=L, height=L, species=8, empty=0.0,
        mcs=int(mcs if mcs is not None else L * L),
        mobility=mobility,
        epsilon=None if mobility > 0 else 0.0,
        mu=1.0, sigma=1.0, engine=engine, seed=seed, **kw)


def survival_probabilities(alpha: float, beta: float, gamma: float = 1.0,
                           L: int = 100, n_trials: int = 20,
                           mcs: Optional[int] = None, mobility: float = 0.0,
                           key: Optional[jax.Array] = None,
                           engine: str = "batched",
                           trial_devices: Optional[int] = None
                           ) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (per-species survival probability [8], n-survivors histogram
    [9]) over device-sharded IID trials — the quantity behind paper Figs
    4.9-4.13. Trials run in device-parallel chunks with streamed per-chunk
    statistics (trials.run_trials); stasis early-exit is safe here because
    a species can never re-appear after stasis, so the survival mask is
    frozen from that point on."""
    params = park_params(L=L, mcs=mcs, mobility=mobility, engine=engine)
    dom = park_alliance_network(alpha, beta, gamma)
    res = run_trials(params, dom, n_trials, key=key,
                     trial_devices=trial_devices)
    return res.survival_probabilities(), res.survivors_hist()


def species5_extinction_std(L_values, mcs_values, alpha: float = 0.15,
                            beta: float = 0.75, gamma: float = 1.0,
                            n_trials: int = 20, seed: int = 0,
                            engine: str = "batched",
                            trial_devices: Optional[int] = None
                            ) -> np.ndarray:
    """Replication of paper Table 4.2: std of species-5 extinction indicator
    across IID trials, for each (MCS, L). Returns (len(mcs), len(L)).

    Each cell runs its trial batch through the chunked, device-sharded
    driver, so the Park protocol (2000 serial runs in the original)
    executes in device-parallel chunks with streamed statistics."""
    out = np.zeros((len(mcs_values), len(L_values)))
    dom = park_alliance_network(alpha, beta, gamma)
    for j, L in enumerate(L_values):
        for i, mcs in enumerate(mcs_values):
            if mcs == 0:
                out[i, j] = 0.0
                continue
            params = park_params(L=L, mcs=mcs, engine=engine, seed=seed)
            res = run_trials(params, dom, n_trials,
                             key=jax.random.PRNGKey(seed + 17 * j + i),
                             trial_devices=trial_devices)
            extinct5 = 1.0 - res.survival[:, 4].astype(np.float64)
            out[i, j] = float(extinct5.std())
    return out
