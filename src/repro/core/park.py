"""Park, Chen & Szolnoki (2023) eight-species alliance model (paper §4.3.2)
plus the mobility extension of the Cliff & Sinadjan companion paper (App. C).

Since the scenario layer (DESIGN.md §10) this module is a thin invocation
of the registered ``probabilistic`` scenario: the physics (S=8, the
(alpha, beta, gamma) rate network, epsilon=0 unless the companion paper's
mobility knob is turned) lives in ``core.scenarios``; here we only compose
it with an engine/run config and stream the trial statistics the figures
read.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np

from .params import EscgParams
from .scenarios import EngineConfig, RunConfig, Scenario, make_scenario
from .trials import run_trials


def park_scenario(alpha: float = 0.15, beta: float = 0.75,
                  gamma: float = 1.0, mobility: float = 0.0) -> Scenario:
    """The registered ``probabilistic`` preset with Park's rate knobs."""
    return make_scenario("probabilistic", alpha=alpha, beta=beta,
                         gamma=gamma, mobility=mobility)


def park_params(L: int = 100, mcs: Optional[int] = None,
                mobility: float = 0.0, engine: str = "batched",
                seed: int = 0, **kw) -> EscgParams:
    """Paper/Park defaults: S=8, no empties... Park's model has no empty
    sites initially; interactions produce empties which reproduction refills.
    Terminates after L^2 MCS (paper Fig 4.9/4.10). Back-compat facade:
    composes the ``probabilistic`` scenario and applies ``**kw`` as flat
    ``EscgParams`` overrides."""
    p = park_scenario(mobility=mobility).to_legacy(
        EngineConfig(engine=engine),
        RunConfig(length=L, height=L, seed=seed,
                  mcs=int(mcs if mcs is not None else L * L)))
    return p.replace(**kw).validate() if kw else p


def survival_probabilities(alpha: float, beta: float, gamma: float = 1.0,
                           L: int = 100, n_trials: int = 20,
                           mcs: Optional[int] = None, mobility: float = 0.0,
                           key: Optional[jax.Array] = None,
                           engine: str = "batched",
                           trial_devices: Optional[int] = None
                           ) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (per-species survival probability [8], n-survivors histogram
    [9]) over device-sharded IID trials — the quantity behind paper Figs
    4.9-4.13. One scenario invocation: the trial driver derives the
    (alpha, beta, gamma) dominance network from the scenario registry and
    runs device-parallel chunks with streamed per-chunk statistics
    (trials.run_trials); stasis early-exit is safe here because a species
    can never re-appear after stasis, so the survival mask is frozen from
    that point on."""
    sc = park_scenario(alpha, beta, gamma, mobility)
    res = run_trials(sc, None, n_trials, key=key,
                     trial_devices=trial_devices,
                     engine_config=EngineConfig(engine=engine),
                     run_config=RunConfig(
                         length=L, height=L,
                         mcs=int(mcs if mcs is not None else L * L)))
    return res.survival_probabilities(), res.survivors_hist()


def species5_extinction_std(L_values, mcs_values, alpha: float = 0.15,
                            beta: float = 0.75, gamma: float = 1.0,
                            n_trials: int = 20, seed: int = 0,
                            engine: str = "batched",
                            trial_devices: Optional[int] = None
                            ) -> np.ndarray:
    """Replication of paper Table 4.2: std of species-5 extinction indicator
    across IID trials, for each (MCS, L). Returns (len(mcs), len(L)).

    Each cell is one scenario invocation through the chunked,
    device-sharded driver, so the Park protocol (2000 serial runs in the
    original) executes in device-parallel chunks with streamed
    statistics."""
    out = np.zeros((len(mcs_values), len(L_values)))
    sc = park_scenario(alpha, beta, gamma)
    for j, L in enumerate(L_values):
        for i, mcs in enumerate(mcs_values):
            if mcs == 0:
                out[i, j] = 0.0
                continue
            res = run_trials(sc, None, n_trials,
                             key=jax.random.PRNGKey(seed + 17 * j + i),
                             trial_devices=trial_devices,
                             engine_config=EngineConfig(engine=engine),
                             run_config=RunConfig(length=L, height=L,
                                                  mcs=mcs, seed=seed))
            extinct5 = 1.0 - res.survival[:, 4].astype(np.float64)
            out[i, j] = float(extinct5.std())
    return out
