"""Core ESCG engine — the paper's contribution as a composable JAX module."""
from . import batched, dominance, engines, io, lattice, metrics, park
from . import reference, rng, rules, simulation, sublattice, trials
from .engines import BuiltEngine, EngineCaps, EngineSpec, engine_names
from .engines import engine_specs, get_engine, register
from .params import ENGINES, EscgParams
from .simulation import SimResult, run_trials, simulate
from .trials import TrialResult

__all__ = [
    "EscgParams", "ENGINES", "SimResult", "simulate", "run_trials",
    "TrialResult",
    "BuiltEngine", "EngineCaps", "EngineSpec", "engine_names",
    "engine_specs", "get_engine", "register",
    "batched", "dominance", "engines", "io", "lattice", "metrics", "park",
    "reference", "rng", "rules", "simulation", "sublattice", "trials",
]
