"""Core ESCG engine — the paper's contribution as a composable JAX module."""
from . import batched, dominance, engines, io, lattice, metrics, observables
from . import park, reference, results, rng, rules, scenarios, simulation
from . import sublattice, trials
from .engines import BuiltEngine, EngineCaps, EngineSpec, engine_names
from .engines import engine_specs, get_engine, register
from .params import EscgParams
from .results import RunResult
from .scenarios import (EngineConfig, RunConfig, Scenario, ScenarioCaps,
                        ScenarioSpec, compose, decompose, get_scenario,
                        make_scenario, register_scenario, scenario_names,
                        scenario_specs)
from .simulation import SimResult, run_trials, simulate
from .trials import TrialResult


def __getattr__(name: str):
    # live back-compat alias (see params.__getattr__): a from-import here
    # would re-freeze the engine list at package-import time
    if name == "ENGINES":
        return engine_names()
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "EscgParams", "ENGINES", "SimResult", "simulate", "run_trials",
    "TrialResult", "RunResult",
    "BuiltEngine", "EngineCaps", "EngineSpec", "engine_names",
    "engine_specs", "get_engine", "register",
    "Scenario", "ScenarioCaps", "ScenarioSpec", "EngineConfig", "RunConfig",
    "register_scenario", "scenario_names", "scenario_specs", "get_scenario",
    "make_scenario", "compose", "decompose",
    "batched", "dominance", "engines", "io", "lattice", "metrics",
    "observables", "park", "reference", "results", "rng", "rules",
    "scenarios", "simulation", "sublattice", "trials",
]
