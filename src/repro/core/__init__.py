"""Core ESCG engine — the paper's contribution as a composable JAX module."""
from . import batched, dominance, io, lattice, metrics, park, reference
from . import rng, rules, simulation, sublattice
from .params import ENGINES, EscgParams
from .simulation import SimResult, run_trials, simulate

__all__ = [
    "EscgParams", "ENGINES", "SimResult", "simulate", "run_trials",
    "batched", "dominance", "io", "lattice", "metrics", "park",
    "reference", "rng", "rules", "simulation", "sublattice",
]
