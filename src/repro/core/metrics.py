"""Densities, stasis detection and survival statistics (paper §3.2.2, §4.3)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def counts(grid: jax.Array, species: int) -> jax.Array:
    return jnp.bincount(grid.reshape(-1).astype(jnp.int32),
                        length=species + 1)


def densities(grid: jax.Array, species: int) -> jax.Array:
    return counts(grid, species) / grid.size


def alive_species(cnt: jax.Array) -> jax.Array:
    """Number of species (excluding empties) with non-zero population."""
    return jnp.sum((cnt[1:] > 0).astype(jnp.int32))


def stasis(cnt: jax.Array) -> jax.Array:
    """Paper §3.2.2: stable when at most one species remains active (even if
    several non-competing species could coexist, migration keeps the grid
    changing, so stasis is strictly monoculture-or-dead)."""
    return alive_species(cnt) <= 1


def survivors(grid: jax.Array, species: int) -> jax.Array:
    """Bool[S] survival mask, 0-indexed by species-1 (Park experiments)."""
    return counts(grid, species)[1:] > 0


def first_extinction_mcs(density_history: np.ndarray, sp: int) -> int:
    """First MCS at which species ``sp`` (1-indexed) has zero density;
    -1 if it never goes extinct. ``density_history``: (T, S+1)."""
    col = np.asarray(density_history)[:, sp]
    idx = np.nonzero(col == 0.0)[0]
    return int(idx[0]) if idx.size else -1
