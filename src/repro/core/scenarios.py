"""Scenario layer — registry-driven study definitions (DESIGN.md §10).

The paper's platform claim is *configurability*: one simulator replicating
many ESCG studies (classic RPS, Zhong's ablated RPSLS, Park's probabilistic
eight-species alliances, parametric N-species cycles). ``EscgParams``
conflates three concerns — WHAT is simulated, HOW one MCS is computed, and
HOW LONG / WHERE the run happens — so every new study meant hand-editing
drivers. This module decomposes the config API into three composable frozen
dataclasses:

* :class:`Scenario` — the physics of one study: species count, dominance
  network, action rates (mu / sigma / epsilon), boundary condition,
  neighbourhood, initial-condition knobs. Presets register in a first-class
  registry (``@register_scenario`` + :class:`ScenarioCaps` capability
  metadata), exactly mirroring the engine registry in ``engines.py``:
  the CLI (``--scenario NAME``, ``--listScenarios``), the README scenario
  matrix and the validation layer all resolve scenarios through this table.
* :class:`EngineConfig` — engine selection: engine name, tile, cell dtype,
  device layouts (``shard_grid`` / ``mesh_shape``), local kernel.
* :class:`RunConfig` — run control: lattice size, MCS budget, chunking,
  seed, output/IO knobs.

``compose(scenario, engine, run)`` assembles the three into the legacy
``EscgParams`` (the back-compat facade — bit-identical trajectories, JSON
round-trip preserved); ``decompose(params)`` inverts it. Parametric
families resolve by name suffix: ``make_scenario("nspecies7")`` is the
7-species cyclic game.
"""
from __future__ import annotations

import dataclasses
import hashlib
import inspect
import json
import re
from dataclasses import dataclass, field
from typing import Callable, Dict, Mapping, Optional, Tuple, Union

import numpy as np

from . import dominance as dom_mod
from .engines import get_engine
from .params import EscgParams, parse_observables

__all__ = [
    "Scenario", "ScenarioCaps", "ScenarioSpec", "EngineConfig", "RunConfig",
    "register_scenario", "scenario_names", "scenario_specs", "get_scenario",
    "make_scenario", "scenario_key", "compose", "decompose",
    "resolve_config",
    "scenario_from_cli", "engine_config_from_args", "run_config_from_args",
    "SCENARIO_CLI_FIELDS",
]

BOUNDARIES = ("flux", "reflect")   # periodic torus | reflecting walls


def _freeze_extras(extras) -> Tuple[Tuple[str, float], ...]:
    items = extras.items() if isinstance(extras, Mapping) else extras
    return tuple(sorted((str(k), float(v)) for k, v in items))


# ------------------------------- Scenario --------------------------------- #

@dataclass(frozen=True)
class Scenario:
    """WHAT is simulated — the physics of one ESCG study.

    Pure data (JSON round-trippable): the dominance network is *derived*,
    not stored — :meth:`dominance` dispatches on ``name`` through the
    scenario registry, so a ``Scenario`` parsed back from JSON rebuilds
    exactly the matrix its preset defines. Ad-hoc scenarios (empty or
    unregistered ``name``) fall back to the legacy default, the circulant
    ``C(S, {1})`` cycle — the same default ``simulate`` applies when called
    with ``dom=None``.
    """
    name: str = ""                 # registry name ('' = ad-hoc / legacy)
    species: int = 3
    neighbourhood: int = 4         # 4 = von Neumann, 8 = Moore
    mobility: float = 3e-5         # M: typical area explored per unit time
    mu: float = 1.0                # interaction rate
    sigma: float = 1.0             # reproduction rate
    epsilon: Optional[float] = None  # migration; None = 2*M*N (paper)
    boundary: str = "flux"         # 'flux' (periodic torus) | 'reflect'
    empty: float = 0.0             # initial empty-cell probability
    # preset-specific knobs (e.g. Park's alpha/beta/gamma), stored sorted
    # so equal scenarios compare equal
    extras: Tuple[Tuple[str, float], ...] = ()

    def __post_init__(self):
        # normalize extras in the constructor itself: a dict (arbitrary
        # iteration order) or an unsorted tuple would otherwise produce a
        # Scenario that compares unequal to — and content-hashes
        # differently from (scenario_key) — the same physics built sorted.
        object.__setattr__(self, "extras", _freeze_extras(self.extras))

    @property
    def flux(self) -> bool:
        return self.boundary == "flux"

    def extra(self, key: str, default: Optional[float] = None) -> float:
        for k, v in self.extras:
            if k == key:
                return v
        if default is None:
            raise KeyError(f"scenario {self.name!r} has no extra {key!r}")
        return float(default)

    def validate(self) -> "Scenario":
        if self.boundary not in BOUNDARIES:
            raise ValueError(f"boundary must be one of {BOUNDARIES}, "
                             f"got {self.boundary!r}")
        if self.species < 1:
            raise ValueError("species >= 1")
        if self.neighbourhood not in (4, 8):
            raise ValueError("neighbourhood must be 4 or 8")
        if not (0.0 <= self.empty <= 1.0):
            raise ValueError("empty in [0,1]")
        spec = _spec_for(self.name)
        if spec is not None and spec.caps.species is not None \
                and self.species != spec.caps.species:
            raise ValueError(
                f"scenario {self.name!r} is a fixed {spec.caps.species}-"
                f"species study; cannot override species={self.species}")
        return self

    def dominance(self) -> np.ndarray:
        """The (S+1, S+1) dominance network of this scenario, rebuilt from
        the registry spec (or the legacy circulant default when ad-hoc)."""
        spec = _spec_for(self.name)
        if spec is not None and spec.dominance is not None:
            return spec.dominance(self)
        return dom_mod.circulant(self.species)

    def to_legacy(self, engine: Optional["EngineConfig"] = None,
                  run: Optional["RunConfig"] = None) -> EscgParams:
        """Compose into the back-compat ``EscgParams`` facade."""
        return compose(self, engine, run)

    def replace(self, **kw) -> "Scenario":
        if "extras" in kw:
            kw["extras"] = _freeze_extras(kw["extras"])
        return dataclasses.replace(self, **kw)

    # ------------------------------ io -------------------------------- #
    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self))

    @staticmethod
    def from_json(s: str) -> "Scenario":
        d = json.loads(s)
        d["extras"] = _freeze_extras(d.get("extras", ()))
        return Scenario(**d)


# ------------------------- EngineConfig / RunConfig ------------------------ #

@dataclass(frozen=True)
class EngineConfig:
    """HOW one MCS is computed — engine selection and device layout.

    Mirrors the TPU-adaptation block of ``EscgParams``; legality of every
    knob is still decided by the engine registry (``EngineCaps``) when the
    config is composed and validated."""
    engine: str = "batched"
    cell_dtype: str = "int32"
    tile: Tuple[int, int] = (8, 32)
    shard_grid: Optional[Tuple[int, int]] = None
    mesh_shape: Optional[Tuple[int, int, int]] = None
    local_kernel: str = "jnp"
    # MCS fused per kernel launch (multi-MCS megakernel; fused-Philox
    # engines only — see EscgParams.k_mcs)
    k_mcs: int = 1

    def replace(self, **kw) -> "EngineConfig":
        return dataclasses.replace(self, **kw)

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self))

    @staticmethod
    def from_json(s: str) -> "EngineConfig":
        d = json.loads(s)
        d["tile"] = tuple(d["tile"])
        for k in ("shard_grid", "mesh_shape"):
            if d.get(k) is not None:
                d[k] = tuple(d[k])
        return EngineConfig(**d)


@dataclass(frozen=True)
class RunConfig:
    """HOW LONG / WHERE — run control, lattice extent and IO."""
    length: int = 200
    height: int = 200
    mcs: int = 100_000
    chunk_mcs: int = 100
    seed: int = 0
    print_frequency: int = 200
    num_randoms: int = 0
    max_step: bool = False
    save: bool = False
    resume: bool = False
    out_dir: str = "escg_out"
    # streaming observables (DESIGN.md §11): None = defer to the
    # scenario's ScenarioCaps.observables (filled by resolve_config on
    # scenario-first driver calls); () = explicitly off; a tuple of
    # registered names selects exactly those.
    observables: Optional[Tuple[str, ...]] = None
    obs_capacity: int = 0          # ring rows; 0 = auto (one chunk)

    def replace(self, **kw) -> "RunConfig":
        return dataclasses.replace(self, **kw)

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self))

    @staticmethod
    def from_json(s: str) -> "RunConfig":
        d = json.loads(s)
        if d.get("observables") is not None:
            d["observables"] = tuple(d["observables"])
        return RunConfig(**d)


# ------------------------------- registry ---------------------------------- #

@dataclass(frozen=True)
class ScenarioCaps:
    """Static capability metadata, consumed by validation, the CLI scenario
    matrix and the docs (mirror of ``EngineCaps``)."""
    species: Optional[int] = None  # fixed species count; None = parametric
    rates: str = "deterministic"   # dominance entries: {0,1} or [0,1] rates
    boundary: str = "flux"         # boundary condition the study assumes
    init: str = "uniform"          # initial-condition sampler family
    observables: Tuple[str, ...] = ()  # the statistics the study reads
    description: str = ""
    paper: str = ""                # study / figure the preset reproduces


@dataclass(frozen=True)
class ScenarioSpec:
    name: str
    caps: ScenarioCaps
    build: Callable[..., Scenario] = field(repr=False, default=None)
    # dominance(scenario) -> (S+1, S+1) float32; None = circulant default
    dominance: Optional[Callable[[Scenario], np.ndarray]] = field(
        repr=False, default=None)


_REGISTRY: Dict[str, ScenarioSpec] = {}


def register_scenario(name: str, caps: ScenarioCaps,
                      dominance: Optional[Callable[[Scenario], np.ndarray]]
                      = None):
    """Decorator: register ``build(**overrides) -> Scenario`` under
    ``name``. Re-registration replaces (same contract as engines)."""
    def deco(build_fn):
        _REGISTRY[name] = ScenarioSpec(name=name, caps=caps, build=build_fn,
                                       dominance=dominance)
        return build_fn
    return deco


def scenario_names() -> Tuple[str, ...]:
    return tuple(_REGISTRY)


def scenario_specs() -> Tuple[ScenarioSpec, ...]:
    return tuple(_REGISTRY.values())


_PARAMETRIC = re.compile(r"^([A-Za-z_]+?)(\d+)$")


def _resolve_name(name: str):
    """(spec, extra_kwargs) for ``name`` — parametric families resolve by
    suffix: 'nspecies7' -> the 'nspecies' family with S=7."""
    if name in _REGISTRY:
        return _REGISTRY[name], {}
    m = _PARAMETRIC.match(name)
    if m and m.group(1) in _REGISTRY \
            and _REGISTRY[m.group(1)].caps.species is None:
        return _REGISTRY[m.group(1)], {"S": int(m.group(2))}
    raise ValueError(
        f"unknown scenario {name!r}; registered: {scenario_names()} "
        "(parametric families accept a numeric suffix, e.g. 'nspecies7')")


def _spec_for(name: str) -> Optional[ScenarioSpec]:
    """Registry spec for ``name``, or None for ad-hoc scenarios."""
    if not name:
        return None
    try:
        return _resolve_name(name)[0]
    except ValueError:
        return None


def get_scenario(name: str) -> ScenarioSpec:
    return _resolve_name(name)[0]


def make_scenario(name: str, **overrides) -> Scenario:
    """Build a registered scenario preset. ``overrides`` route by name:
    knobs the builder declares (e.g. ``alpha=`` for 'probabilistic') go to
    the builder — preserving preset-internal coupling like Park's
    mobility->epsilon rule — and plain ``Scenario`` field names are
    applied on top of the built preset."""
    spec, kw = _resolve_name(name)
    accepts = {p.name for p in inspect.signature(spec.build)
               .parameters.values()
               if p.kind in (p.POSITIONAL_OR_KEYWORD, p.KEYWORD_ONLY)}
    field_names = {f.name for f in dataclasses.fields(Scenario)}
    build_kw, field_kw = {}, {}
    for k, v in overrides.items():
        if k in accepts:
            build_kw[k] = v
        elif k in field_names:
            field_kw[k] = v
        else:
            raise ValueError(
                f"scenario {name!r} accepts builder knobs {sorted(accepts)}"
                f" and Scenario fields {sorted(field_names)}; got {k!r}")
    sc = spec.build(**kw, **build_kw)
    if field_kw:
        sc = sc.replace(**field_kw)
    return sc.validate()


# ------------------------------ composition -------------------------------- #

def compose(scenario: Scenario, engine: Optional[EngineConfig] = None,
            run: Optional[RunConfig] = None) -> EscgParams:
    """Assemble (Scenario, EngineConfig, RunConfig) into a validated
    ``EscgParams`` — the back-compat facade every driver still consumes.

    Boundary legality is checked here with NAMES on both sides: a
    ``flux_only`` engine (see ``EngineCaps``) cannot run a reflecting
    scenario, and the error says which scenario met which engine instead
    of the facade's anonymous flux complaint."""
    engine = engine or EngineConfig()
    run = run or RunConfig()
    scenario = scenario.validate()
    ecaps = get_engine(engine.engine).caps
    if ecaps.flux_only and not scenario.flux:
        raise ValueError(
            f"scenario {scenario.name or '<ad-hoc>'!r} uses reflecting "
            f"boundaries (boundary='reflect') but engine "
            f"{engine.engine!r} is flux-only (periodic torus); run it on a "
            "boundary-agnostic engine such as 'reference' or 'batched', "
            "or set boundary='flux'")
    return EscgParams(
        length=run.length, height=run.height, mcs=run.mcs,
        neighbourhood=scenario.neighbourhood,
        print_frequency=run.print_frequency, mobility=scenario.mobility,
        species=scenario.species, flux=scenario.flux, empty=scenario.empty,
        save=run.save, resume=run.resume, num_randoms=run.num_randoms,
        max_step=run.max_step, mu=scenario.mu, sigma=scenario.sigma,
        epsilon=scenario.epsilon, engine=engine.engine,
        cell_dtype=engine.cell_dtype, tile=engine.tile, seed=run.seed,
        chunk_mcs=run.chunk_mcs, out_dir=run.out_dir,
        shard_grid=engine.shard_grid, mesh_shape=engine.mesh_shape,
        local_kernel=engine.local_kernel, k_mcs=engine.k_mcs,
        observables=(() if run.observables is None
                     else tuple(run.observables)),
        obs_capacity=run.obs_capacity).validate()


def decompose(params: EscgParams, name: str = ""
              ) -> Tuple[Scenario, EngineConfig, RunConfig]:
    """Invert :func:`compose`: split a flat ``EscgParams`` into the three
    layers. ``compose(*decompose(p)) == p`` for every valid ``p``."""
    sc = Scenario(
        name=name, species=params.species,
        neighbourhood=params.neighbourhood, mobility=params.mobility,
        mu=params.mu, sigma=params.sigma, epsilon=params.epsilon,
        boundary="flux" if params.flux else "reflect", empty=params.empty)
    eng = EngineConfig(
        engine=params.engine, cell_dtype=params.cell_dtype,
        tile=params.tile, shard_grid=params.shard_grid,
        mesh_shape=params.mesh_shape, local_kernel=params.local_kernel,
        k_mcs=params.k_mcs)
    run = RunConfig(
        length=params.length, height=params.height, mcs=params.mcs,
        chunk_mcs=params.chunk_mcs, seed=params.seed,
        print_frequency=params.print_frequency,
        num_randoms=params.num_randoms, max_step=params.max_step,
        save=params.save, resume=params.resume, out_dir=params.out_dir,
        observables=tuple(params.observables),
        obs_capacity=params.obs_capacity)
    return sc, eng, run


def resolve_config(params: Union[EscgParams, Scenario],
                   dom: Optional[np.ndarray] = None,
                   engine_config: Optional[EngineConfig] = None,
                   run_config: Optional[RunConfig] = None):
    """Normalize a driver's config input to ``(EscgParams, dom)``.

    Drivers (``simulate``, ``run_trials``, ``engines.build``) accept either
    the legacy facade or a :class:`Scenario` (+ optional engine/run
    configs). For scenarios with ``dom=None`` the dominance network comes
    from the registry — the study carries its own physics.

    Scenario-first calls additionally make ``ScenarioCaps.observables``
    load-bearing (DESIGN.md §11): unless the ``RunConfig`` pins
    ``observables`` (a tuple, ``()`` = explicitly off), the composed
    params stream the preset's declared observables — filtered to names
    the observable registry actually implements (caps also list
    result-level statistics like ``survival`` that are not streaming
    observables)."""
    if isinstance(params, Scenario):
        if dom is None:
            dom = params.dominance()
        composed = compose(params, engine_config, run_config)
        if run_config is None or run_config.observables is None:
            obs = scenario_observables(params.name)
            if obs:
                composed = composed.replace(observables=obs).validate()
        return composed, dom
    if engine_config is not None or run_config is not None:
        raise ValueError(
            "engine_config/run_config only apply when the first argument "
            "is a Scenario; an EscgParams already carries both layers")
    return params, dom


def scenario_key(scenario: Scenario) -> str:
    """Stable content hash of a scenario's physics (DESIGN.md §12).

    The serving layer's compiled-engine cache keys on this: two requests
    share a compiled program only when every physics field — species,
    neighbourhood, rates, boundary, init occupancy, preset extras, and
    the registry name the dominance network derives from — is identical.
    The hash is canonical-JSON (sorted keys, normalized extras) over the
    dataclass fields, so it is reproducible across processes and Python
    hash seeds; never Python ``hash()`` (PYTHONHASHSEED-dependent).
    Floats serialize via ``repr`` (shortest round-trip), so equal values
    hash equal on every platform JAX supports."""
    d = dataclasses.asdict(scenario)
    # asdict keeps the (already sorted — Scenario.__post_init__) extras
    # tuple; JSON encodes it as nested lists, canonically
    payload = json.dumps(d, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(payload.encode("utf-8")).hexdigest()[:16]


def scenario_observables(name: str) -> Tuple[str, ...]:
    """The streaming subset of a scenario's ``ScenarioCaps.observables``
    (DESIGN.md §11): declared names that resolve in the observable
    registry, in declaration order. Caps may also declare result-level
    statistics (``survival``, ``stasis_mcs``, ...) — those are computed
    by the drivers from the same streams, not registered as device
    observables, and are filtered out here. Ad-hoc scenarios: ()."""
    from . import observables as obs_mod  # lazy: keep import graph acyclic
    spec = _spec_for(name)
    if spec is None:
        return ()
    registered = set(obs_mod.observable_names())
    return tuple(o for o in spec.caps.observables if o in registered)


# ------------------------------ CLI bridging ------------------------------- #

# Scenario-owned CLI fields: with --scenario these come from the preset
# unless the flag is explicitly given (detected as differing from the
# argparse default — a user re-passing the exact default defers to the
# preset, which is the documented behaviour).
SCENARIO_CLI_FIELDS = ("species", "neighbourhood", "mobility", "mu",
                       "sigma", "epsilon", "empty", "flux")


def scenario_from_cli(args, parser) -> Scenario:
    """Build the ``--scenario`` preset, overridden by explicitly-passed
    scenario-owned CLI flags (see ``SCENARIO_CLI_FIELDS``). ``parser`` is
    required: its defaults are how "explicitly passed" is detected —
    without it every argparse default would silently override the
    preset's physics."""
    sc = make_scenario(args.scenario)
    over = {}
    for f in SCENARIO_CLI_FIELDS:
        v = getattr(args, f, None)
        if v is None or v == parser.get_default(f):
            continue
        over[f] = v
    if "flux" in over:
        over["boundary"] = "flux" if over.pop("flux") else "reflect"
    return sc.replace(**over).validate() if over else sc


def engine_config_from_args(args) -> EngineConfig:
    kw = {}
    for f in dataclasses.fields(EngineConfig):
        v = getattr(args, f.name, None)
        if v is not None:
            kw[f.name] = tuple(v) if isinstance(v, list) else v
    return EngineConfig(**kw)


def run_config_from_args(args) -> RunConfig:
    kw = {}
    for f in dataclasses.fields(RunConfig):
        v = getattr(args, f.name, None)
        if v is not None:
            kw[f.name] = v
    if "observables" in kw:
        # the CLI carries a comma-separated string; None (flag absent)
        # never lands here, so absent keeps the defer-to-scenario default
        kw["observables"] = parse_observables(kw["observables"])
    return RunConfig(**kw)


# ------------------------------ presets ------------------------------------ #
# The paper's study space (§3.1, §4.3): each preset is one published ESCG
# study, reproduced end-to-end by composing it with any engine/run config.

@register_scenario("park3", ScenarioCaps(
    species=3, rates="deterministic",
    observables=("densities", "interface_length", "stasis_mcs"),
    description="paper baseline rock-paper-scissors: cyclic C(3,{1}) "
                "dominance at low mobility (RMF spiral regime)",
    paper="Tables 3.1/3.2; Reichenbach-Mobilia-Frey Fig 1.1"),
    dominance=lambda sc: dom_mod.RPS())
def _build_park3() -> Scenario:
    return Scenario(name="park3", species=3, mobility=3e-5)


@register_scenario("zhong_density", ScenarioCaps(
    species=5, rates="deterministic",
    observables=("extinction_mcs", "densities"),
    description="Zhong et al. (2022) ablated RPSLS: the Rock-crushes-"
                "Scissors edge removed; Paper goes extinct in 200-600 MCS",
    paper="paper §3.1.2, Figs 3.2/3.3 (Zhong Fig 2)"),
    dominance=lambda sc: dom_mod.zhong_ablated_rpsls())
def _build_zhong_density() -> Scenario:
    return Scenario(name="zhong_density", species=5, mobility=1e-4)


def _nspecies_dom(sc: Scenario) -> np.ndarray:
    # canonical cyclic family: C(S,{1,2}) from 5 species up (RPSLS and
    # its generalizations), C(S,{1}) below — the same rule the CLI default
    # applies
    offs = (1, 2) if sc.species >= 5 else (1,)
    return dom_mod.circulant(sc.species, offs)


@register_scenario("nspecies", ScenarioCaps(
    species=None, rates="deterministic",
    observables=("densities", "survival"),
    description="parametric S-species cyclic game: C(S,{1,2}) for S >= 5 "
                "(RPSLS family), C(S,{1}) below; name suffix sets S "
                "('nspecies7')",
    paper="paper §3.1.1 circulant C(S,K) family"),
    dominance=_nspecies_dom)
def _build_nspecies(S: int = 5) -> Scenario:
    if S < 1:
        raise ValueError("nspecies family needs S >= 1")
    return Scenario(name=f"nspecies{S}", species=S, mobility=3e-5)


def _park_alliance_dom(sc: Scenario) -> np.ndarray:
    return dom_mod.park_alliance_network(
        sc.extra("alpha"), sc.extra("beta"), sc.extra("gamma"))


@register_scenario("probabilistic", ScenarioCaps(
    species=8, rates="probabilistic",
    observables=("survival", "survivors_hist", "extinction_mcs"),
    description="Park, Chen & Szolnoki (2023) eight-species alliances: "
                "probabilistic (alpha, beta, gamma) rates, no migration, "
                "terminate after L^2 MCS",
    paper="paper §4.3.2, Figs 4.9-4.13, Table 4.2"),
    dominance=_park_alliance_dom)
def _build_probabilistic(alpha: float = 0.15, beta: float = 0.75,
                         gamma: float = 1.0,
                         mobility: float = 0.0) -> Scenario:
    # Park et al. have no migration; the companion paper's extension is
    # mobility > 0 (then epsilon reverts to the 2*M*N default)
    return Scenario(name="probabilistic", species=8, mobility=mobility,
                    epsilon=None if mobility > 0 else 0.0,
                    extras=_freeze_extras(
                        {"alpha": alpha, "beta": beta, "gamma": gamma}))


def _asym_dom(sc: Scenario) -> np.ndarray:
    r12, r23, r31 = (sc.extra("r12"), sc.extra("r23"), sc.extra("r31"))
    return dom_mod.from_dense(np.array([[0.0, r12, 0.0],
                                        [0.0, 0.0, r23],
                                        [r31, 0.0, 0.0]], dtype=np.float32))


@register_scenario("asym_rps", ScenarioCaps(
    species=3, rates="probabilistic",
    observables=("densities", "survival"),
    description="asymmetric-dominance RPS: the three cyclic edges carry "
                "unequal kill rates (r12, r23, r31) — breaks the "
                "symmetric-coexistence degeneracy",
    paper="paper §3.1.1 rate generalization (Park-style asymmetry)"),
    dominance=_asym_dom)
def _build_asym_rps(r12: float = 1.0, r23: float = 0.7,
                    r31: float = 0.4) -> Scenario:
    return Scenario(name="asym_rps", species=3, mobility=3e-5,
                    extras=_freeze_extras(
                        {"r12": r12, "r23": r23, "r31": r31}))
