"""State import/export — paper parity (grid.csv / params.csv / dominance.csv,
--save / --resume, §3.2.2) plus a binary .npz fast path used by the runtime
checkpointing layer."""
from __future__ import annotations

import json
import os
from typing import Optional, Tuple

import numpy as np

from . import dominance as dom_mod
from .params import EscgParams


def export_grid_csv(path: str, grid: np.ndarray, mcs: int) -> None:
    """Paper format: one CSV row per lattice row; final line = last MCS."""
    grid = np.asarray(grid)
    with open(path, "w") as f:
        for row in grid:
            f.write(",".join(str(int(v)) for v in row) + "\n")
        f.write(f"{int(mcs)}\n")


def import_grid_csv(path: str) -> Tuple[np.ndarray, int]:
    with open(path) as f:
        lines = [l.strip() for l in f if l.strip()]
    mcs = int(lines[-1])
    grid = np.array([[int(v) for v in l.split(",")] for l in lines[:-1]],
                    dtype=np.int32)
    return grid, mcs


def save_state(out_dir: str, params: EscgParams, grid: np.ndarray, mcs: int,
               dom: np.ndarray, key: Optional[np.ndarray] = None) -> None:
    os.makedirs(out_dir, exist_ok=True)
    export_grid_csv(os.path.join(out_dir, "grid.csv"), grid, mcs)
    with open(os.path.join(out_dir, "params.csv"), "w") as f:
        f.write(params.to_json())
    with open(os.path.join(out_dir, "dominance.csv"), "w") as f:
        f.write(dom_mod.to_csv(dom))
    # binary fast path (atomic)
    tmp = os.path.join(out_dir, ".state.npz.tmp")
    blob = {"grid": np.asarray(grid, np.int32), "mcs": np.int64(mcs),
            "dom": np.asarray(dom, np.float32)}
    if key is not None:
        blob["key"] = np.asarray(key)
    with open(tmp, "wb") as f:
        np.savez(f, **blob)
    os.replace(tmp, os.path.join(out_dir, "state.npz"))


def load_state(out_dir: str):
    """Returns (params, grid, mcs, dom, key|None). Prefers the npz fast path,
    falls back to the paper CSV format."""
    with open(os.path.join(out_dir, "params.csv")) as f:
        params = EscgParams.from_json(f.read())
    npz_path = os.path.join(out_dir, "state.npz")
    if os.path.exists(npz_path):
        z = np.load(npz_path)
        key = z["key"] if "key" in z.files else None
        return params, z["grid"], int(z["mcs"]), z["dom"], key
    grid, mcs = import_grid_csv(os.path.join(out_dir, "grid.csv"))
    with open(os.path.join(out_dir, "dominance.csv")) as f:
        dom = dom_mod.from_csv(f.read())
    return params, grid, mcs, dom, None


def export_densities_csv(path: str, density_history: np.ndarray) -> None:
    hist = np.asarray(density_history)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        s = hist.shape[1] - 1
        f.write("mcs,empty," + ",".join(f"s{i}" for i in range(1, s + 1))
                + "\n")
        for t, row in enumerate(hist):
            f.write(f"{t}," + ",".join(f"{v:.6f}" for v in row) + "\n")


def save_snapshot(out_dir: str, grid: np.ndarray, mcs: int) -> str:
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"snapshot_{mcs:08d}.npy")
    np.save(path, np.asarray(grid, np.int32))
    return path
