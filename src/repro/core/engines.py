"""First-class engine registry (DESIGN.md §2, grown multi-device).

Every update engine registers a builder plus capability metadata here;
``simulation.simulate`` / ``run_trials`` and the CLI resolve engines through
this table instead of an if/elif ladder. Adding an engine is one
``@register(...)`` decorator — params validation, CLI choices and the
README engine matrix all follow automatically.

Engine contract: ``build(params, dom) -> BuiltEngine`` where
``one_mcs(grid, key) -> (grid, kept, attempts)`` advances one Monte-Carlo
step (N elementary updates) fully on-device. ``grid_sharding`` is non-None
for multi-device engines: the driver ``device_put``s the lattice onto it
before the first chunk and every array op thereafter stays device-resident.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from functools import partial
from typing import Callable, Dict, NamedTuple, Optional, Tuple, TYPE_CHECKING

import jax
import jax.numpy as jnp

from . import batched as batched_mod
from . import reference as reference_mod
from . import sublattice as sublattice_mod
from .rng import proposal_batch, round_shift, tile_stream_batch

if TYPE_CHECKING:  # avoid a runtime cycle: params validates via this module
    from .params import EscgParams


class BuiltEngine(NamedTuple):
    """A ready-to-run engine instance for one (params, dominance) pair.

    ``one_mcs`` advances ONE lattice. Engines whose caps declare a ``pod``
    mesh axis (DESIGN.md §6) additionally provide ``one_mcs_batch``, which
    advances a whole batch of IID trial lattices laid out on a composed
    ``('pod', 'rows', 'cols')`` mesh: ``batch_sharding``/``key_sharding``
    are where the trial driver must place the stacked grids and per-trial
    keys, and ``pod_width`` is the trial-axis device count the batch must
    pad to.
    """
    one_mcs: Callable[[jax.Array, jax.Array],
                      Tuple[jax.Array, jax.Array, jax.Array]]
    grid_sharding: Optional[jax.sharding.Sharding] = None
    one_mcs_batch: Optional[Callable[[jax.Array, jax.Array],
                                     Tuple[jax.Array, jax.Array,
                                           jax.Array]]] = None
    batch_sharding: Optional[jax.sharding.Sharding] = None
    key_sharding: Optional[jax.sharding.Sharding] = None
    pod_width: int = 1
    # k_mcs megakernel entry points (DESIGN.md §6). ``multi_mcs(grid, key,
    # k_steps)`` advances K Monte-Carlo steps in one launch and returns
    # (grid, key', counts, kept, attempts) with counts (K, species+1) —
    # the per-MCS density stream the drivers would otherwise compute one
    # metrics.counts at a time. k_steps is static at trace time. The key
    # is split INSIDE exactly like K driver-level one_mcs calls would, so
    # trajectories stay bit-identical to k_mcs=1. ``multi_mcs_batch`` is
    # the composed-mesh analog over a trial batch: (grids, keys, k_steps)
    # -> (grids, keys', counts (n, K, species+1), kept (n,), att (n,)).
    multi_mcs: Optional[Callable] = None
    multi_mcs_batch: Optional[Callable] = None
    # observable hook (DESIGN.md §11): ``observe(grid, counts) ->
    # (obs_width,) float32`` — one streamed ring-buffer row, evaluated
    # inside the drivers' jitted chunks at per-MCS cadence. Non-None
    # exactly when ``params.observables`` is non-empty; ``engines.build``
    # attaches the registry-generic implementation
    # (observables.build_observe) for every engine family, so the
    # supported set is identical across sublattice/sharded/sharded_pod x
    # local kernels by construction. Must never consume PRNG state —
    # observables-on/off bit-identity is part of the engine contract.
    observe: Optional[Callable[[jax.Array, jax.Array], jax.Array]] = None


@dataclass(frozen=True)
class EngineCaps:
    """Static capability metadata, consumed by params validation, the
    trial runner and the docs engine matrix (DESIGN.md §2)."""
    flux_only: bool = False    # requires periodic (torus) boundaries
    tiled: bool = False        # consumes params.tile; tile must divide grid
    multi_device: bool = False  # domain-decomposed across jax.devices()
    vmappable: bool = True     # usable under vmap (trials.run_trials)
    trial_shardable: bool = True  # safe to shard the vmapped trial axis
                               # across devices (DESIGN.md §4); requires
                               # vmappable and no internal collectives
    mesh_axes: Tuple[str, ...] = ()  # device-mesh axes the engine owns;
                               # ('rows','cols') = grid decomposition (§5),
                               # ('pod','rows','cols') = composed trial x
                               # grid mesh (§6). Consumed by params
                               # validation of params.mesh_shape and by the
                               # trial runner's composition check.
    local_kernels: Tuple[str, ...] = ()  # values of params.local_kernel the
                               # engine accepts ('jnp', 'pallas', 'fused');
                               # empty = the knob is ignored
    multi_mcs: bool = False    # supports params.k_mcs > 1 (the grid-
                               # resident multi-MCS megakernel, DESIGN.md
                               # §6); only meaningful for the fused-Philox
                               # family — its in-kernel counter schedule
                               # is what makes K steps per launch possible
    equiv_oracle: Optional[str] = None  # engine this one is bit-identical
                               # to at the one_mcs level (same key -> same
                               # trajectory); drives the registry-wide
                               # cross-engine equivalence suite
    observables: Optional[Tuple[str, ...]] = None
                               # streaming observables (DESIGN.md §11)
                               # the engine supports; None = the full
                               # registry (core/observables.py) — every
                               # registered observable is a pure jit-level
                               # grid/counts read, so engines only
                               # restrict this when their step hides the
                               # lattice from XLA. Params validation
                               # checks requested names against it.
    equiv_oracles: Tuple[Tuple[str, str], ...] = ()
                               # per-local-kernel oracle overrides as
                               # (local_kernel, oracle) pairs: a local
                               # kernel with its own PRNG scheme belongs to
                               # a different bit-identity family (e.g.
                               # 'fused' -> 'pallas_fused'); resolve via
                               # oracle_for()
    description: str = ""
    paper: str = ""            # paper algorithm / figure it reproduces

    def oracle_for(self, local_kernel: str = "jnp") -> Optional[str]:
        """The bit-identity oracle engine for this engine running with
        ``local_kernel`` — ``equiv_oracles`` overrides first, then the
        kernel-independent ``equiv_oracle`` (DESIGN.md §2). The
        equivalence suite (tests/test_engine_equivalence.py) enforces one
        contract per (engine, local kernel) pair through this."""
        for lk, oracle in self.equiv_oracles:
            if lk == local_kernel:
                return oracle
        return self.equiv_oracle

    @property
    def pod_composable(self) -> bool:
        """True when the trial axis rides a ``pod`` mesh axis: the trial
        driver may run IID batches of this engine on a composed
        ``('pod', 'rows', 'cols')`` mesh (DESIGN.md §6)."""
        return "pod" in self.mesh_axes

    @property
    def trial_axis(self) -> str:
        """Human-readable trial-axis support (engine matrix column)."""
        if self.pod_composable:
            return "pod×grid composed mesh"
        if self.vmappable and self.trial_shardable:
            return "pod-sharded vmap"
        if self.vmappable:
            return "vmap (1 device)"
        return "—"


@dataclass(frozen=True)
class EngineSpec:
    name: str
    caps: EngineCaps
    build: Callable[["EscgParams", jax.Array], BuiltEngine] = field(
        repr=False, default=None)


_REGISTRY: Dict[str, EngineSpec] = {}


def register(name: str, caps: EngineCaps):
    """Decorator: register ``build(params, dom) -> BuiltEngine`` under
    ``name``. Re-registration replaces (supports hot reload in notebooks)."""
    def deco(build_fn):
        _REGISTRY[name] = EngineSpec(name=name, caps=caps, build=build_fn)
        return build_fn
    return deco


def engine_names() -> Tuple[str, ...]:
    return tuple(_REGISTRY)


def engine_specs() -> Tuple[EngineSpec, ...]:
    return tuple(_REGISTRY.values())


def get_engine(name: str) -> EngineSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown engine {name!r}; registered: {engine_names()}"
        ) from None


def validate_params(p: "EscgParams") -> None:
    """Capability-driven validation (called from EscgParams.validate).

    Mesh-layout legality lives HERE, with the registry, not with the
    drivers: an engine's ``mesh_axes`` decide whether ``params.mesh_shape``
    is meaningful and what rank it must have (DESIGN.md §6)."""
    spec = get_engine(p.engine)
    if spec.caps.flux_only and not p.flux:
        raise ValueError(
            f"engine {p.engine!r} requires flux (periodic) boundaries; "
            "use reference/batched for reflecting boundaries")
    if spec.caps.tiled:
        th, tw = p.tile
        if th < 3 or tw < 3:
            raise ValueError("tile dims must be >= 3 (need interior)")
        if p.height % th or p.length % tw:
            raise ValueError(f"tile {p.tile} must divide lattice "
                             f"{p.height}x{p.length}")
    if spec.caps.multi_device and p.shard_grid is not None:
        dr, dc = p.shard_grid
        if dr < 1 or dc < 1:
            raise ValueError("shard_grid dims must be >= 1")
    if p.local_kernel not in ("jnp", "pallas", "fused"):
        raise ValueError("local_kernel must be 'jnp', 'pallas' or 'fused'")
    # engines that declare supported kernels accept exactly those; engines
    # with no declaration ignore the knob (same rule as params.tile)
    if spec.caps.local_kernels and \
            p.local_kernel not in spec.caps.local_kernels:
        raise ValueError(
            f"engine {p.engine!r} supports local_kernel in "
            f"{spec.caps.local_kernels}, got {p.local_kernel!r}")
    if p.k_mcs < 1:
        raise ValueError(f"k_mcs must be >= 1, got {p.k_mcs}")
    if p.k_mcs > 1:
        if not spec.caps.multi_mcs:
            raise ValueError(
                f"engine {p.engine!r} does not support k_mcs > 1 (the "
                "multi-MCS megakernel belongs to the fused-Philox family: "
                "pallas_fused, or sharded/sharded_pod with "
                "local_kernel='fused')")
        if spec.caps.local_kernels and p.local_kernel != "fused":
            raise ValueError(
                f"k_mcs > 1 requires local_kernel='fused' on engine "
                f"{p.engine!r} (got {p.local_kernel!r}): only the "
                "in-kernel Philox schedule can thread K MCS through one "
                "launch")
    if p.obs_capacity < 0:
        raise ValueError(f"obs_capacity must be >= 0, got {p.obs_capacity}")
    if p.observables:
        from . import observables as obs_mod  # lazy: avoid import cycle
        for name in p.observables:
            obs_mod.get_observable(name)     # raises on unknown names
            if spec.caps.observables is not None \
                    and name not in spec.caps.observables:
                raise ValueError(
                    f"engine {p.engine!r} supports observables "
                    f"{spec.caps.observables}, got {name!r} "
                    "(EngineCaps.observables rails, DESIGN.md §11)")
    if p.mesh_shape is not None:
        if not spec.caps.pod_composable:
            raise ValueError(
                f"engine {p.engine!r} does not lay devices on a "
                f"('pod','rows','cols') mesh (mesh_axes="
                f"{spec.caps.mesh_axes}); mesh_shape only applies to "
                "pod-composable engines like 'sharded_pod'")
        if len(p.mesh_shape) != len(spec.caps.mesh_axes):
            raise ValueError(
                f"mesh_shape {p.mesh_shape} must have one entry per mesh "
                f"axis {spec.caps.mesh_axes}")
        if any(d < 1 for d in p.mesh_shape):
            raise ValueError("mesh_shape dims must be >= 1")


def build(params: "EscgParams", dom: Optional[jax.Array] = None
          ) -> BuiltEngine:
    """Resolve ``params.engine`` and build its one-MCS function.

    Also accepts a scenario-layer ``Scenario`` (DESIGN.md §10) in place of
    the flat params: it is composed with default engine/run configs, and
    ``dom=None`` then resolves the dominance network through the scenario
    registry."""
    from .scenarios import resolve_config  # lazy: scenarios imports us
    params, dom = resolve_config(params, dom)
    if dom is None:
        # same default as simulate(): the circulant C(S,{1}) cycle
        from . import dominance as dom_mod
        dom = dom_mod.circulant(params.species)
    if not isinstance(dom, jax.Array):
        dom = jnp.asarray(dom, jnp.float32)
    built = get_engine(params.engine).build(params, dom)
    if params.observables and built.observe is None:
        # registry-generic observe hook (DESIGN.md §11): one jit-level
        # implementation serves every engine family — on sharded grids
        # the reductions lower to per-shard partials + all-reduce, the
        # same path as the stasis counts. Builders may pre-attach a
        # specialized hook; absent that, every engine gets the same set.
        from . import observables as obs_mod  # lazy: avoid import cycle
        hook = obs_mod.build_observe(params)
        if built.grid_sharding is not None:
            # pin the row replicated across the grid mesh: domain-
            # decomposed engines step through shard_map(check_rep=False)
            # regions, and without the constraint the partitioner may
            # combine per-device ring updates by SUMMING the row across
            # a mesh axis (observed 2x counts with the snapshot
            # observable's block reshape in the program)
            rep = jax.sharding.NamedSharding(
                built.grid_sharding.mesh, jax.sharding.PartitionSpec())
            inner = hook

            def hook(grid, counts, _inner=inner, _rep=rep):
                return jax.lax.with_sharding_constraint(
                    _inner(grid, counts), _rep)
        built = built._replace(observe=hook)
    return built


# --------------------------- registered engines --------------------------- #

def _pick_sub_batches(n: int, want: int = 8) -> int:
    for d in (want, 4, 2, 1):
        if n % d == 0:
            return d
    return 1


def _tiled_setup(p: "EscgParams"):
    """Shared tile bookkeeping for the sublattice-family engines."""
    th, tw = p.tile
    n_tiles = (p.height // th) * (p.length // tw)
    k_per_tile = max(1, math.ceil(p.n_cells / n_tiles))
    interior = (th - 2) * (tw - 2)
    return th, tw, n_tiles, k_per_tile, interior


def fused_round_inputs(key: jax.Array, th: int, tw: int):
    """Per-MCS (Philox seed words, window shift) schedule of the
    fused-PRNG family: seed = the raw key words, shift keyed by
    ``fold_in(key, 1)``. THE single definition shared by the
    ``pallas_fused`` engine and the sharded engines'
    ``local_kernel='fused'`` path — their bit-identity contract
    (``EngineCaps.equiv_oracles``) depends on there being exactly one."""
    seed = jax.random.key_data(key).astype(jnp.uint32)[-2:]
    shift = round_shift(jax.random.fold_in(key, 1), th, tw)
    return seed, shift


def multi_round_inputs(key: jax.Array, th: int, tw: int, k_steps: int):
    """The K-step fused schedule: ``(key', seeds (K, 2), shifts (K, 2))``.

    Replays EXACTLY the driver's per-MCS key chain — ``key, k1 =
    split(key); fused_round_inputs(k1, ...)`` K times — so a megakernel
    consuming (seeds[t], shifts[t]) at step t is bit-identical to K
    driver-level ``one_mcs`` calls, and the returned key equals the
    driver's key after K MCS (the k_mcs=1 / k_mcs=K equivalence contract).
    ``k_steps`` is a static Python int (one trace per distinct K)."""
    seeds, shifts = [], []
    for _ in range(k_steps):
        key, k1 = jax.random.split(key)
        seed, shift = fused_round_inputs(k1, th, tw)
        seeds.append(seed)
        shifts.append(shift)
    if not seeds:
        return key, jnp.zeros((0, 2), jnp.uint32), jnp.zeros((0, 2),
                                                             jnp.int32)
    return key, jnp.stack(seeds), jnp.stack(shifts)


@register("reference", EngineCaps(
    description="sequential oracle; one proposal at a time via lax.scan",
    paper="Algorithm 3.2/3.3 (single-threaded baseline)"))
def _build_reference(p: "EscgParams", dom: jax.Array) -> BuiltEngine:
    t_eps, t_eps_mu = p.action_thresholds()
    n = p.n_cells

    def one_mcs(grid, key):
        batch = proposal_batch(key, n, n, p.neighbourhood)
        grid, kept = reference_mod.run_proposals(
            grid, batch, t_eps, t_eps_mu, dom, p.flux)
        return grid, kept, jnp.int32(n)
    return BuiltEngine(one_mcs)


@register("batched", EngineCaps(
    description="scatter-min conflict arbitration over proposal sub-batches",
    paper="Algorithm 3.5/3.6 (CUDA port, E2)"))
def _build_batched(p: "EscgParams", dom: jax.Array) -> BuiltEngine:
    t_eps, t_eps_mu = p.action_thresholds()
    n = p.n_cells
    n_sub = _pick_sub_batches(n)
    b_sub = n // n_sub

    def one_mcs(grid, key):
        def body(carry, k):
            g, kept = carry
            batch = proposal_batch(k, b_sub, n, p.neighbourhood)
            g, k2 = batched_mod.run_proposals(
                g, batch, t_eps, t_eps_mu, dom, p.flux)
            return (g, kept + k2), None
        keys = jax.random.split(key, n_sub)
        (grid, kept), _ = jax.lax.scan(body, (grid, jnp.int32(0)), keys)
        return grid, kept, jnp.int32(n)
    return BuiltEngine(one_mcs)


def _build_tiled(p: "EscgParams", dom: jax.Array, run_round) -> BuiltEngine:
    """Shared builder for the shifted-window engines (jnp and Pallas).

    Proposals come from per-tile counter-based streams (tile_stream_batch),
    so the trajectory is a function of (key, tile id) only — the sharded
    engine regenerates identical streams shard-locally and stays
    bit-identical to this single-device path.

    §Perf H3 iter-1: never roll back. Densities / survival statistics are
    translation-invariant on the torus, so the lattice frame is allowed to
    drift by the accumulated shift (composition of uniform shifts stays
    uniform). Halves the roll traffic per round.
    """
    th, tw, n_tiles, k_per_tile, interior = _tiled_setup(p)
    tile_ids = jnp.arange(n_tiles, dtype=jnp.int32)

    def one_mcs(grid, key):
        kp, ks = jax.random.split(key)
        props = tile_stream_batch(kp, tile_ids, k_per_tile, interior,
                                  p.neighbourhood)
        shift = round_shift(ks, th, tw)
        grid = run_round(grid, props, shift, dom=dom)
        attempts = jnp.int32(n_tiles * k_per_tile)
        return grid, attempts, attempts
    return BuiltEngine(one_mcs)


@register("sublattice", EngineCaps(
    flux_only=True, tiled=True,
    description="shifted-window synchronous sublattice, pure jnp (E3)",
    paper="maxStep §4.2.4 redesigned for tiles (Fig 4.3)"))
def _build_sublattice(p: "EscgParams", dom: jax.Array) -> BuiltEngine:
    t_eps, t_eps_mu = p.action_thresholds()
    run_round = partial(sublattice_mod.run_round, tile_shape=p.tile,
                        t_eps=t_eps, t_eps_mu=t_eps_mu, roll_back=False)
    return _build_tiled(p, dom, run_round)


@register("pallas", EngineCaps(
    flux_only=True, tiled=True, equiv_oracle="sublattice",
    description="sublattice round as a Pallas TPU kernel (VMEM-resident)",
    paper="maxStep §4.2.4, kernelized (Fig 4.3)"))
def _build_pallas(p: "EscgParams", dom: jax.Array) -> BuiltEngine:
    from ..kernels import ops as kernel_ops  # lazy: avoid cycles
    t_eps, t_eps_mu = p.action_thresholds()
    run_round = partial(kernel_ops.escg_round, tile_shape=p.tile,
                        t_eps=t_eps, t_eps_mu=t_eps_mu, roll_back=False)
    return _build_tiled(p, dom, run_round)


@register("pallas_fused", EngineCaps(
    flux_only=True, tiled=True, multi_mcs=True,
    description="Pallas kernel with in-kernel Philox proposal derivation "
                "(zero proposal HBM traffic)",
    paper="numRandoms buffer §3.2.1 eliminated (Fig 4.2)"))
def _build_pallas_fused(p: "EscgParams", dom: jax.Array) -> BuiltEngine:
    from ..kernels import ops as kernel_ops  # lazy: avoid cycles
    t_eps, t_eps_mu = p.action_thresholds()
    th, tw, n_tiles, k_per_tile, _ = _tiled_setup(p)

    def one_mcs(grid, key):
        # per-MCS Philox key = the raw PRNG key words; round_idx = 0
        seed, shift = fused_round_inputs(key, th, tw)
        grid = kernel_ops.escg_round_fused(
            grid, seed, jnp.uint32(0), shift, dom, p.tile, k_per_tile,
            t_eps, t_eps_mu, p.neighbourhood, roll_back=False)
        attempts = jnp.int32(n_tiles * k_per_tile)
        return grid, attempts, attempts

    def multi_mcs(grid, key, k_steps):
        # K MCS per launch: the megakernel consumes the K-step schedule
        # and banks per-step species counts in-kernel
        key, seeds, shifts = multi_round_inputs(key, th, tw, k_steps)
        grid, counts = kernel_ops.escg_rounds_fused(
            grid, seeds, shifts, dom, p.tile, k_per_tile, t_eps, t_eps_mu,
            p.species, p.neighbourhood)
        attempts = jnp.int32(k_steps * n_tiles * k_per_tile)
        return grid, key, counts, attempts, attempts
    return BuiltEngine(one_mcs, multi_mcs=multi_mcs)


@register("sharded", EngineCaps(
    flux_only=True, tiled=True, multi_device=True, vmappable=False,
    trial_shardable=False, mesh_axes=("rows", "cols"),
    local_kernels=("jnp", "pallas", "fused"), multi_mcs=True,
    equiv_oracle="sublattice",
    equiv_oracles=(("fused", "pallas_fused"),),
    description="domain-decomposed across devices: shard_map + ppermute "
                "halo exchange, per-tile Philox streams, psum stasis counts",
    paper="size scaling beyond one device (Fig 4.3, L=3200)"))
def _build_sharded(p: "EscgParams", dom: jax.Array) -> BuiltEngine:
    from . import sharded as sharded_mod  # lazy: pulls parallel/ helpers
    return sharded_mod.build_engine(p, dom)


@register("sharded_pod", EngineCaps(
    flux_only=True, tiled=True, multi_device=True, vmappable=False,
    trial_shardable=False, mesh_axes=("pod", "rows", "cols"),
    local_kernels=("jnp", "pallas", "fused"), multi_mcs=True,
    equiv_oracle="sublattice",
    equiv_oracles=(("fused", "pallas_fused"),),
    description="composed trial x grid mesh: IID trials sharded over "
                "'pod', each lattice halo-exchanged over ('rows','cols'); "
                "same per-tile streams as sharded",
    paper="mass replication of large lattices (Fig 4.3 x Table 4.2)"))
def _build_sharded_pod(p: "EscgParams", dom: jax.Array) -> BuiltEngine:
    from . import sharded_pod as pod_mod  # lazy: pulls parallel/ helpers
    return pod_mod.build_engine(p, dom)
