"""E1: exact sequential engine (paper Algorithm 3.2/3.3 semantics).

``lax.scan`` over elementary steps — the single-threaded baseline the paper
benchmarks against, and the oracle every parallel engine is validated on.

``drop_conflicts=True`` switches to the *sequential shadow* of the batched
engine: a proposal is skipped (not applied) when any earlier proposal in the
same arbitration window touched either of its cells. With matching windows
this reproduces ``batched.run_proposals`` bit-for-bit (tests rely on it).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from . import lattice
from .rng import ProposalBatch
from .rules import apply_pair


def run_proposals(grid: jax.Array, batch: ProposalBatch, t_eps: float,
                  t_eps_mu: float, dom: jax.Array, flux: bool = True,
                  drop_conflicts: bool = False
                  ) -> Tuple[jax.Array, jax.Array]:
    """Apply a proposal stream strictly in order. Returns (grid, n_applied)."""
    h, w = grid.shape
    g0 = grid.reshape(-1)
    ni = lattice.neighbor_index(batch.cell, batch.dirn, h, w, flux)

    def body(carry, p):
        g, touched = carry
        i, n_i, ua, ud = p
        s = g[i]
        n = g[n_i]
        ns, nn = apply_pair(s, n, ua, ud, t_eps, t_eps_mu, dom)
        if drop_conflicts:
            keep = ~(touched[i] | touched[n_i])
            ns = jnp.where(keep, ns, s)
            nn = jnp.where(keep, nn, n)
            # NB: cells count as touched even for dropped proposals — this is
            # exactly the scatter-min arbitration rule of the batched engine.
            touched = touched.at[i].set(True).at[n_i].set(True)
        else:
            keep = jnp.bool_(True)
        g = g.at[i].set(ns)
        g = g.at[n_i].set(nn)
        return (g, touched), keep

    touched0 = jnp.zeros_like(g0, dtype=jnp.bool_)
    (g, _), kept = lax.scan(
        body, (g0, touched0), (batch.cell, ni, batch.u_act, batch.u_dom))
    return g.reshape(h, w), jnp.sum(kept.astype(jnp.int32))
