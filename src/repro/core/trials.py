"""Device-sharded IID trial subsystem — the *pod* axis (DESIGN.md §4).

The paper's replication studies hinge on massed IID trials (Park et al. ran
2000 serial repetitions for one figure; the dissertation's Table 4.2 runs 20
per cell). PR 1 decomposed one big lattice across devices (the grid axis);
this module carries the orthogonal axis: many independent lattices, one per
trial, vmapped on-device and **sharded across all local devices** over the
trial dimension. sPEGG (Okamoto & Amarasekare 2016) and the wafer-scale
agent-evolution work both show this population/trial axis is where
eco-evolutionary GPU throughput compounds.

Design invariants (tested in tests/test_trials.py):

* **Per-trial fold-in keys.** Trial ``t`` uses
  ``jax.random.fold_in(base_key, t)`` — a pure function of the base key and
  the *global* trial index, never of the trial count, the padding, or the
  device layout. Results are therefore bit-identical for any
  ``trial_devices`` and any padding, and a prefix of a larger run equals the
  smaller run (the same counter-based idiom as ``rng.tile_stream_batch`` on
  the grid axis).
* **Padding to device multiples.** ``n_trials`` is padded up to a multiple
  of the device count; padded trials run (they are indistinguishable to
  XLA's SPMD partitioner) and are dropped from every statistic on the host.
* **Chunked streaming.** ``n_mcs`` executes in jitted chunks of
  ``chunk_mcs`` (one ``lax.scan`` per chunk, fully device-resident). The
  host only ever sees per-chunk per-MCS alive-species masks — never the
  grids — and streams stasis / extinction statistics between chunks instead
  of materializing one monolithic ``(trials, mcs, ...)`` history.
* **Async stat streaming.** By default (``async_stats=True``) the driver
  keeps one chunk in flight ahead of the host: chunk k+1 is dispatched
  before chunk k's masks are pulled to the host, so stasis/extinction
  accounting overlaps device compute (double-buffered device-to-host
  copies; JAX dispatch is asynchronous). Bit-identical to the synchronous
  schedule — the speculative chunk past an early-exit is dropped unread.
* **Chunked stasis early-exit.** Per-trial stasis (<= 1 species alive,
  paper §3.2.2) is recorded at exact per-MCS resolution from the streamed
  masks, but the driver only *stops* at chunk granularity, and only once
  EVERY live trial has entered stasis (a vmapped batch advances in
  lock-step; finished trials are monocultures whose survival mask can no
  longer change, so running them to the barrier is harmless).
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from functools import partial
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from . import dominance as dom_mod
from . import engines, lattice, metrics
from . import observables as obs_mod
from .params import EscgParams
from .results import decode_observables, encode_observables

POD_AXIS = "pod"   # mesh axis name for the trial dimension


# ------------------------------ TrialResult ------------------------------- #

@dataclass
class TrialResult:
    """Streamed statistics of a batch of IID trials.

    Grids are intentionally absent: at pod scale (thousands of trials) the
    lattices stay device-resident and only the statistics below ever reach
    the host.

    ``observables`` (the ``RunResult`` protocol surface, core/results.py)
    maps registered observable names to per-trial streams flushed from
    the device ring buffer, shape ``(n_trials, T, ...)`` with T the rows
    the ring retained (== MCS consumed when the capacity covers every
    chunk; lossy wraparound drops the oldest rows per chunk otherwise).
    Empty when ``params.observables`` was empty. Note
    ``observables['densities']`` is the per-MCS density *stream*; the
    ``densities`` field keeps its legacy meaning of final densities.
    """
    survival: np.ndarray       # (n_trials, S) bool — species alive at end
    densities: np.ndarray      # (n_trials, S + 1) — final densities, col 0
                               # = empties
    stasis_mcs: np.ndarray     # (n_trials,) int — first MCS with <= 1
                               # species alive; -1 if never
    extinction_mcs: np.ndarray  # (n_trials, S) int — first MCS each species
                               # hit zero population; 0 = absent at init,
                               # -1 = never went extinct
    mcs_completed: int         # MCS every trial actually ran
    kept_fraction: float       # applied / attempted proposals (E2 audit)
    n_trials: int
    n_devices: int             # devices the batch ran on: the pod width
                               # for vmapped engines, the full composed
                               # ('pod','rows','cols') mesh size for
                               # pod-composable engines (DESIGN.md §6)
    observables: dict = field(default_factory=dict)

    # --------------------------- statistics ---------------------------- #
    @property
    def species(self) -> int:
        return self.survival.shape[1]

    def survival_probabilities(self) -> np.ndarray:
        """Per-species survival probability, shape (S,) — Park Figs 4.9+."""
        return self.survival.mean(axis=0)

    def survivors_hist(self) -> np.ndarray:
        """Histogram over the number of surviving species, shape (S + 1,),
        normalized to sum to 1 (Park n-survivor statistics)."""
        s = self.species
        return (np.bincount(self.survival.sum(axis=1).astype(np.int64),
                            minlength=s + 1)[:s + 1] / self.n_trials)

    def extinction_probability(self, sp: int) -> float:
        """P(species ``sp``, 1-indexed, extinct at end) over trials."""
        return float(1.0 - self.survival[:, sp - 1].mean())

    def mean_densities(self) -> np.ndarray:
        return self.densities.mean(axis=0)

    # ------------------------------ io --------------------------------- #
    def to_json(self) -> str:
        return json.dumps({
            "survival": self.survival.astype(int).tolist(),
            "densities": self.densities.tolist(),
            "stasis_mcs": self.stasis_mcs.tolist(),
            "extinction_mcs": self.extinction_mcs.tolist(),
            "mcs_completed": self.mcs_completed,
            "kept_fraction": self.kept_fraction,
            "n_trials": self.n_trials,
            "n_devices": self.n_devices,
            "observables": encode_observables(self.observables),
        })

    @staticmethod
    def from_json(s: str) -> "TrialResult":
        d = json.loads(s)
        return TrialResult(
            survival=np.asarray(d["survival"], dtype=bool),
            densities=np.asarray(d["densities"], dtype=np.float64),
            stasis_mcs=np.asarray(d["stasis_mcs"], dtype=np.int64),
            extinction_mcs=np.asarray(d["extinction_mcs"], dtype=np.int64),
            mcs_completed=int(d["mcs_completed"]),
            kept_fraction=float(d["kept_fraction"]),
            n_trials=int(d["n_trials"]),
            n_devices=int(d["n_devices"]),
            observables=decode_observables(d.get("observables", {})),
        )


# --------------------------- pod-axis sharding ----------------------------- #

def pod_sharding(trial_devices: Optional[int] = None) -> NamedSharding:
    """Batch sharding over the leading (trial) axis on a 1-D ``pod`` mesh
    of the first ``trial_devices`` local devices (all of them when None)."""
    devs = jax.local_devices()
    d = len(devs) if trial_devices is None else int(trial_devices)
    if d < 1:
        raise ValueError("trial_devices must be >= 1")
    if d > len(devs):
        raise ValueError(f"trial_devices={d} but only {len(devs)} local "
                         "devices are available")
    mesh = Mesh(np.asarray(devs[:d]), (POD_AXIS,))
    return NamedSharding(mesh, P(POD_AXIS))


def pad_trials(n_trials: int, n_devices: int) -> int:
    """Smallest multiple of ``n_devices`` that is >= ``n_trials`` (XLA SPMD
    needs the batch axis to divide evenly across the pod mesh)."""
    return -(-n_trials // n_devices) * n_devices


def fold_trial_keys(key: jax.Array, n: int, start: int = 0) -> jax.Array:
    """Per-trial run keys ``fold_in(key, t)`` for global trial indices
    ``start .. start + n - 1`` (see module docstring: the key is a pure
    function of the base key and the GLOBAL trial index, never of the
    batch composition — a prefix of a larger run equals the smaller run,
    and the serving layer packs many requests' key blocks into one batch
    without perturbing any trajectory)."""
    return jax.vmap(lambda i: jax.random.fold_in(key, i))(
        jnp.arange(start, start + n, dtype=jnp.int32))


def make_trial_init(p: EscgParams,
                    sharding: Optional[NamedSharding] = None,
                    grid_sharding: Optional[NamedSharding] = None):
    """``init(trial_keys) -> (grids, keys)``: initial lattices + run keys
    from per-trial fold-in keys, reusable across calls.

    The returned closure jits the per-trial ``init_one`` ONCE, so a
    long-lived caller (the serving layer's compiled-engine cache) pays
    the init trace a single time per cached engine; ``run_trials``
    routes through the same closure, keeping the two paths bit-identical
    by construction. Placement matches the driver: ``sharding`` places
    the keys BEFORE init (grids are born distributed over the trial
    axis), ``grid_sharding`` optionally reshards the grids afterwards
    (the composed path adds the ('rows','cols') lattice axes)."""
    cell_dt = jnp.dtype(p.cell_dtype)

    @jax.jit
    def init_one(tk):
        kg, kr = jax.random.split(tk)
        g = lattice.init_grid(kg, p.height, p.length, p.species, p.empty,
                              dtype=cell_dt)
        return g, kr

    def init(trial_keys):
        if sharding is not None:
            trial_keys = jax.device_put(trial_keys, sharding)
        grids, keys = jax.vmap(init_one)(trial_keys)
        if grid_sharding is not None:
            grids = jax.device_put(grids, grid_sharding)
        return grids, keys

    return init


def trial_grids_and_keys(p: EscgParams, key: jax.Array, n_pad: int,
                         sharding: Optional[NamedSharding] = None,
                         grid_sharding: Optional[NamedSharding] = None):
    """Initial lattices + per-trial run keys for ``n_pad`` trials.

    Trial ``t``'s key is ``fold_in(key, t)`` (see module docstring); the
    lattice honours ``params.cell_dtype`` exactly like ``simulate`` does
    (the legacy vmap runner silently initialized int32 grids regardless).

    ``sharding`` places the per-trial keys BEFORE init, so grids are born
    distributed over the trial axis (never materialized on one device).
    ``grid_sharding`` optionally resharding the grids afterwards — the
    composed path (§6) uses it to add the ('rows','cols') lattice axes.
    """
    trial_keys = fold_trial_keys(key, n_pad)
    return make_trial_init(p, sharding, grid_sharding)(trial_keys)


# ----------------------------- chunked driver ------------------------------ #

def build_trial_chunk(p: EscgParams, dom: jax.Array,
                      one_mcs: Optional[Callable] = None,
                      built: Optional[engines.BuiltEngine] = None,
                      pipe: Optional[obs_mod.ObsPipeline] = None):
    """chunk(grids, keys, n_mcs<static>) -> (grids, keys, final_counts,
    alive[n, n_mcs, S], kept[n], attempts[n]); jitted, device-resident.
    ``alive`` is the only per-MCS output and is what the host streams
    statistics from.

    Two shapes of engine fit this contract (DESIGN.md §4/§6):

    * vmappable engines: ``one_mcs(grid, key)`` is vmapped over the
      leading trial axis, the per-trial MCS loop is a ``lax.scan``;
    * pod-composable engines (``built.one_mcs_batch`` non-None): the scan
      runs at the batch level and each step advances the whole batch on
      the composed ('pod','rows','cols') mesh.

    Both thread per-trial keys identically (split once per MCS per
    trial), so they are bit-identical for any engine pair whose one-MCS
    functions are.

    With ``pipe`` (an :class:`~.observables.ObsPipeline`) each chunk
    additionally returns the banked per-MCS observable rows, shape
    ``(n_mcs, n, obs_width)`` — the device-side stream
    :func:`build_trial_obs_chunk` copies into the ring buffer. The key
    chain and every other output are bit-identical to ``pipe=None``
    (observables never consume PRNG state). Under ``k_mcs > 1``
    grid-derived slices are lag-held at launch-group boundaries exactly
    as in ``simulation.build_obs_chunk_fn``.
    """
    s = p.species
    if built is not None and built.one_mcs_batch is not None:
        if p.k_mcs > 1:
            multi_batch = built.multi_mcs_batch
            assert multi_batch is not None, \
                f"engine {p.engine!r} validated k_mcs>1 but built no " \
                "multi_mcs_batch"
            k_group = p.k_mcs

            @partial(jax.jit, static_argnames=("n_mcs",))
            def chunk_batch(grids, keys, n_mcs: int):
                n = grids.shape[0]
                q, r = divmod(n_mcs, k_group)
                kept = att = jnp.zeros((n,), jnp.int32)
                parts, row_parts = [], []
                held = (jax.vmap(pipe.grid_values)(grids)
                        if pipe is not None else None)

                def launch_rows(cnts_l, held):
                    # (n, K, S+1) -> (K, n, obs_width), lag-held grid slices
                    return jax.vmap(lambda c: jax.vmap(pipe.row_held)(
                        c, held))(jnp.moveaxis(cnts_l, 1, 0))

                if q:
                    def body(carry, _):
                        g, k, kept, att, held = carry
                        g, k, cnts, k2, a2 = multi_batch(g, k, k_group)
                        rows = (launch_rows(cnts, held)
                                if pipe is not None else jnp.int32(0))
                        if pipe is not None:
                            held = jax.vmap(pipe.grid_values)(g)
                        return (g, k, kept + k2, att + a2, held), (cnts,
                                                                   rows)
                    (grids, keys, kept, att, held), (cnts_q, rows_q) = \
                        jax.lax.scan(body, (grids, keys, kept, att, held),
                                     length=q)
                    # (q, n, K, S + 1) -> (n, q * K, S + 1)
                    parts.append(jnp.moveaxis(cnts_q, 0, 1).reshape(
                        n, q * k_group, s + 1))
                    if pipe is not None:
                        # (q, K, n, W) -> (q * K, n, W)
                        row_parts.append(rows_q.reshape(
                            q * k_group, n, pipe.width))
                if r:
                    grids, keys, cnts_r, k2, a2 = multi_batch(grids, keys,
                                                              r)
                    kept, att = kept + k2, att + a2
                    parts.append(cnts_r)
                    if pipe is not None:
                        row_parts.append(launch_rows(cnts_r, held))
                cnts = jnp.concatenate(parts, axis=1)
                out = (grids, keys, cnts[:, -1], cnts[:, :, 1:] > 0,
                       kept, att)
                if pipe is not None:
                    out += (jnp.concatenate(row_parts, axis=0),)
                return out

            return chunk_batch

        one_mcs_batch = built.one_mcs_batch

        @partial(jax.jit, static_argnames=("n_mcs",))
        def chunk_batch(grids, keys, n_mcs: int):
            zeros = jnp.zeros((grids.shape[0],), jnp.int32)

            def body(carry, _):
                g, k, kept, att = carry
                both = jax.vmap(jax.random.split)(k)
                k, k1 = both[:, 0], both[:, 1]
                g, k2, a2 = one_mcs_batch(g, k1)
                cnts = jax.vmap(lambda x: metrics.counts(x, s))(g)
                rows = (jax.vmap(pipe.row)(g, cnts)
                        if pipe is not None else jnp.int32(0))
                return (g, k, kept + k2, att + a2), (cnts, rows)
            (g, k, kept, att), (cnts, rows) = jax.lax.scan(
                body, (grids, keys, zeros, zeros), length=n_mcs)
            cnts = jnp.moveaxis(cnts, 0, 1)      # (n, n_mcs, S + 1)
            out = (g, k, cnts[:, -1], cnts[:, :, 1:] > 0, kept, att)
            if pipe is not None:
                out += (rows,)                   # (n_mcs, n, W)
            return out

        return chunk_batch

    if one_mcs is None and (built is None and p.k_mcs > 1):
        built = engines.build(p, dom)
    if one_mcs is None:
        one_mcs = (built.one_mcs if built is not None
                   else engines.build(p, dom).one_mcs)
    multi = (built.multi_mcs
             if built is not None and p.k_mcs > 1 else None)

    if p.k_mcs > 1:
        assert multi is not None, \
            f"engine {p.engine!r} validated k_mcs>1 but built no multi_mcs"
        k_group = p.k_mcs

        @partial(jax.jit, static_argnames=("n_mcs",))
        def chunk(grids, keys, n_mcs: int):
            def one(grid, key):
                q, r = divmod(n_mcs, k_group)
                kept = att = jnp.int32(0)
                parts, row_parts = [], []
                held = (pipe.grid_values(grid) if pipe is not None
                        else None)
                if q:
                    def body(carry, _):
                        g, k, kept, att, held = carry
                        g, k, cnts, k2, a2 = multi(g, k, k_group)
                        rows = (jax.vmap(lambda c: pipe.row_held(c, held))(
                            cnts) if pipe is not None else jnp.int32(0))
                        if pipe is not None:
                            held = pipe.grid_values(g)
                        return (g, k, kept + k2, att + a2, held), (cnts,
                                                                   rows)
                    (grid, key, kept, att, held), (cnts_q, rows_q) = \
                        jax.lax.scan(body, (grid, key, kept, att, held),
                                     length=q)
                    parts.append(cnts_q.reshape(q * k_group, s + 1))
                    if pipe is not None:
                        row_parts.append(rows_q.reshape(q * k_group,
                                                        pipe.width))
                if r:
                    grid, key, cnts_r, k2, a2 = multi(grid, key, r)
                    kept, att = kept + k2, att + a2
                    parts.append(cnts_r)
                    if pipe is not None:
                        row_parts.append(jax.vmap(
                            lambda c: pipe.row_held(c, held))(cnts_r))
                cnts = jnp.concatenate(parts, axis=0)
                out = (grid, key, cnts[-1], cnts[:, 1:] > 0, kept, att)
                if pipe is not None:
                    out += (jnp.concatenate(row_parts, axis=0),)
                return out
            out = jax.vmap(one)(grids, keys)
            if pipe is not None:
                # per-trial (n, n_mcs, W) -> ring layout (n_mcs, n, W)
                out = out[:6] + (jnp.moveaxis(out[6], 0, 1),)
            return out

        return chunk

    @partial(jax.jit, static_argnames=("n_mcs",))
    def chunk(grids, keys, n_mcs: int):
        def one(grid, key):
            def body(carry, _):
                g, k, kept, att = carry
                k, k1 = jax.random.split(k)
                g, k2, a2 = one_mcs(g, k1)
                cnt = metrics.counts(g, s)
                row = (pipe.row(g, cnt) if pipe is not None
                       else jnp.int32(0))
                return (g, k, kept + k2, att + a2), (cnt, row)
            (g, k, kept, att), (cnts, rows) = jax.lax.scan(
                body, (grid, key, jnp.int32(0), jnp.int32(0)), length=n_mcs)
            out = (g, k, cnts[-1], cnts[:, 1:] > 0, kept, att)
            if pipe is not None:
                out += (rows,)
            return out
        out = jax.vmap(one)(grids, keys)
        if pipe is not None:
            out = out[:6] + (jnp.moveaxis(out[6], 0, 1),)
        return out

    return chunk


def build_trial_obs_chunk(p: EscgParams, dom: jax.Array,
                          built: Optional[engines.BuiltEngine] = None):
    """Observable-pipeline trial chunk (DESIGN.md §11): ``chunk(grids,
    keys, ring, pos, n_mcs<static>) -> (grids, keys, ring, pos,
    final_counts, alive, kept, attempts)``; returns ``(chunk, pipeline)``.

    The banked per-MCS rows are copied into the device-resident ring
    buffer (shape ``(capacity, n_pad, obs_width)``) inside the jitted
    chunk — the host never sees a per-MCS transfer; ``run_trials``
    flushes the ring once per *consumed* chunk on the same speculative
    double-buffered stream as the alive-masks. Capacity below the chunk
    length drops the oldest rows (documented lossy wraparound; the
    stasis/extinction statistics stream from ``alive``, not the ring).
    """
    pipe = obs_mod.build_pipeline(p)
    inner = build_trial_chunk(p, dom, built=built, pipe=pipe)

    @partial(jax.jit, static_argnames=("n_mcs",))
    def chunk(grids, keys, ring, pos, n_mcs: int):
        grids, keys, cnts, alive, kept, att, rows = inner(grids, keys,
                                                          n_mcs)
        ring, pos = obs_mod.ring_push_many(ring, pos, rows)
        return grids, keys, ring, pos, cnts, alive, kept, att

    return chunk, pipe


def _first_true_mcs(mask: np.ndarray, offset: int) -> np.ndarray:
    """First 1-based MCS index of a True along axis 1 of ``mask``
    (trials-leading), offset by the MCS already completed; -1 where the
    event never happens in this chunk. Works on any trailing shape."""
    hit = mask.any(axis=1)
    first = mask.argmax(axis=1) + offset + 1
    return np.where(hit, first, -1)


def run_trials(params: EscgParams, dom: Optional[np.ndarray] = None,
               n_trials: int = 1, key: Optional[jax.Array] = None,
               n_mcs: Optional[int] = None,
               trial_devices: Optional[int] = None,
               chunk_mcs: Optional[int] = None,
               stop_on_stasis: bool = True,
               hooks: Sequence[Callable[[int, np.ndarray], None]] = (),
               async_stats: bool = True,
               engine_config=None, run_config=None, *,
               engine=None, run=None,
               ) -> TrialResult:
    """Run ``n_trials`` IID simulations, vmapped and device-sharded.

    Scenario-first signature (DESIGN.md §10): ``run_trials(scenario,
    n_trials=..., engine=EngineConfig(...), run=RunConfig(...))`` — the
    primary positional argument is a ``Scenario``; ``dom=None`` derives
    the dominance network from the scenario registry, and the scenario's
    declared observables stream through the device ring buffer
    (DESIGN.md §11) unless ``run.observables`` pins the set. The legacy
    flat form ``run_trials(params, dom, ...)`` still works behind a
    ``DeprecationWarning`` (``engine_config=``/``run_config=`` are the
    equally-deprecated spellings of ``engine=``/``run=``).

    The batch is padded to a multiple of the pod width (``trial_devices``,
    default: all local devices), placed with the trial axis sharded across
    the pod mesh, and advanced in jitted chunks of ``chunk_mcs`` MCS
    (default ``params.chunk_mcs``). Between chunks the host streams
    alive-species masks into per-trial stasis / extinction statistics and —
    when ``stop_on_stasis`` — exits early once every trial has reached
    stasis (see module docstring for the exact chunked semantics).

    Pod-composable engines (``EngineCaps.mesh_axes`` containing 'pod',
    e.g. ``engine='sharded_pod'``) run the same pipeline on a composed
    ``('pod', 'rows', 'cols')`` mesh: trials shard over the pod axis while
    every trial's lattice is additionally domain-decomposed with halo
    exchange (DESIGN.md §6). The device layout comes from
    ``params.mesh_shape`` (``trial_devices`` must stay None) and the batch
    pads to the pod width only. Results are bit-identical to the vmapped
    single-device path for any mesh factorization.

    ``hooks`` fire after every chunk with ``(mcs_done, alive_counts)``
    where ``alive_counts`` is the (n_trials,) number of species alive per
    trial at the chunk boundary.

    ``async_stats`` (default True) streams the per-chunk statistics OFF
    the critical path: chunk k+1 is dispatched (JAX dispatch is
    asynchronous) *before* the host touches chunk k's alive-masks, so the
    stasis/extinction accounting overlaps the next chunk's device compute
    instead of serializing on it (double-buffered device-to-host copies).
    Results are bit-identical either way — the host consumes exactly the
    same arrays in the same order; the one speculative chunk in flight
    past a stasis early-exit is discarded unconsumed, so ``mcs_completed``
    and every statistic match the synchronous schedule exactly.

    Bit-identical for any ``trial_devices`` and any padding: per-trial
    PRNG keys are ``fold_in(key, trial_index)``.

    With ``params.observables`` non-empty the per-MCS observable rows of
    every (padded) trial are banked into a device ring buffer inside each
    chunk and flushed once per CONSUMED chunk — the speculative in-flight
    chunk dropped by a stasis early-exit is never flushed, so the
    observable streams are flush-schedule invariant (identical for
    ``async_stats`` True/False and any chunk length, capacity
    permitting).
    """
    from .scenarios import resolve_config  # lazy: scenarios imports core
    from .simulation import _resolve_call_form  # lazy: avoid cycle
    engine_config, run_config = _resolve_call_form(
        "run_trials", params, engine_config, run_config, engine, run)
    params, dom = resolve_config(params, dom, engine_config, run_config)
    p = params.validate()
    spec = engines.get_engine(p.engine)
    composed = spec.caps.pod_composable
    if composed:
        if trial_devices is not None:
            raise ValueError(
                f"engine {p.engine!r} lays devices on a composed "
                "('pod','rows','cols') mesh — set the pod width through "
                "params.mesh_shape, not trial_devices")
    elif not spec.caps.vmappable:
        raise ValueError(
            f"engine {p.engine!r} is not vmappable (multi-device engines "
            "decompose one lattice); run IID trials with a single-device "
            "engine and shard the trial axis, or compose the two axes "
            "with engine='sharded_pod' (mesh_shape=(pod, rows, cols))")
    if not composed and not spec.caps.trial_shardable \
            and (trial_devices or 1) > 1:
        raise ValueError(f"engine {p.engine!r} does not support trial-axis "
                         "sharding; use trial_devices=1")
    if n_trials < 1:
        raise ValueError("n_trials must be >= 1")
    if dom is None:
        dom = dom_mod.circulant(p.species)
    dom_j = jnp.asarray(dom, jnp.float32)
    if key is None:
        key = jax.random.PRNGKey(p.seed)
    n_mcs = int(n_mcs if n_mcs is not None else p.mcs)
    if chunk_mcs is not None and chunk_mcs < 1:
        raise ValueError("chunk_mcs must be >= 1")
    # n_mcs == 0 is legal: the loop below never runs and the result carries
    # the initial survival mask / densities (legacy vmap-runner behaviour)
    chunk_len = int(chunk_mcs if chunk_mcs is not None
                    else max(1, min(p.chunk_mcs, n_mcs)))

    if composed:
        # composed pod x grid mesh (DESIGN.md §6): the engine owns the
        # device layout; the driver only pads the batch to the pod width
        # and places arrays on the engine's shardings.
        built = engines.build(p, dom_j)
        n_dev = built.batch_sharding.mesh.devices.size
        n_pad = pad_trials(n_trials, built.pod_width)
        # keys are placed pod-sharded BEFORE init, so every trial's grid
        # is born on its pod group; the reshard then only splits each
        # lattice over its group's ('rows','cols') axes — the full batch
        # never materializes on a single device
        grids, keys = trial_grids_and_keys(
            p, key, n_pad, sharding=built.key_sharding,
            grid_sharding=built.batch_sharding)
        pod_mesh = built.key_sharding.mesh
        if p.observables:
            chunk_fn, pipe = build_trial_obs_chunk(p, dom_j, built=built)
        else:
            chunk_fn = build_trial_chunk(p, dom_j, built=built)
    else:
        sharding = (pod_sharding(trial_devices) if spec.caps.trial_shardable
                    else pod_sharding(1))
        n_dev = sharding.mesh.devices.size
        n_pad = pad_trials(n_trials, n_dev)
        grids, keys = trial_grids_and_keys(p, key, n_pad, sharding)
        pod_mesh = sharding.mesh
        if p.observables:
            chunk_fn, pipe = build_trial_obs_chunk(p, dom_j)
        else:
            chunk_fn = build_trial_chunk(p, dom_j)

    obs_on = bool(p.observables)
    ring = pos = None
    rows_all = []
    if obs_on:
        cap = obs_mod.ring_capacity(p, max(1, chunk_len))
        ring, pos = obs_mod.ring_init(cap, (n_pad, pipe.width))
        # ring rows shard with the trial axis — flushes stay device-local
        # per pod group until the host copy
        ring = jax.device_put(
            ring, NamedSharding(pod_mesh, P(None, POD_AXIS)))

    s = p.species
    # species absent at initialization count as extinct at MCS 0
    init_cnts = np.asarray(jax.jit(jax.vmap(
        lambda g: metrics.counts(g, s)))(grids))
    ext = np.where(init_cnts[:, 1:] > 0, -1, 0).astype(np.int64)
    stasis = np.full(n_pad, -1, np.int64)
    surv = init_cnts[:, 1:] > 0
    final_cnts = init_cnts
    kept_tot = att_tot = 0
    done = 0

    # One chunk is kept in flight ahead of the host (async_stats): the
    # np.asarray() below blocks on the chunk being *consumed* while the
    # speculatively dispatched successor already computes. On a stasis
    # early-exit the in-flight chunk is simply dropped — its outputs are
    # never read, so statistics and mcs_completed are schedule-independent.
    def dispatch(grids, keys, ring, pos, m):
        if obs_on:
            return chunk_fn(grids, keys, ring, pos, m)
        g, k, cnts, alive, kept, att = chunk_fn(grids, keys, m)
        return g, k, None, None, cnts, alive, kept, att

    m = min(chunk_len, n_mcs)
    out = dispatch(grids, keys, ring, pos, m) if n_mcs else None
    while out is not None:
        grids, keys, ring, pos, cnts, alive, kept, att = out
        m_next = min(chunk_len, n_mcs - done - m)
        out = (dispatch(grids, keys, ring, pos, m_next)
               if m_next and async_stats else None)

        alive_h = np.asarray(alive)                  # (n_pad, m, S) bool
        if obs_on:
            # one flush per CONSUMED chunk (the in-flight speculative
            # chunk past an early-exit is dropped unflushed)
            rows_all.append(obs_mod.ring_flush(np.asarray(ring), done,
                                               done + m))
        final_cnts = np.asarray(cnts)
        kept_tot += int(np.asarray(kept)[:n_trials].sum())
        att_tot += int(np.asarray(att)[:n_trials].sum())

        first_dead = _first_true_mcs(~alive_h, done)     # (n_pad, S)
        ext = np.where((ext < 0) & (first_dead > 0), first_dead, ext)
        first_stasis = _first_true_mcs(alive_h.sum(axis=2) <= 1, done)
        stasis = np.where((stasis < 0) & (first_stasis > 0),
                          first_stasis, stasis)
        surv = alive_h[:, -1, :]
        done += m
        for hook in hooks:
            hook(done, surv[:n_trials].sum(axis=1))
        if stop_on_stasis and (stasis[:n_trials] >= 0).all():
            break
        if m_next and out is None:                   # async_stats=False
            out = dispatch(grids, keys, ring, pos, m_next)
        m = m_next

    observables = {}
    if obs_on and rows_all:
        rows = np.concatenate(rows_all, axis=0)      # (T, n_pad, W)
        observables = pipe.split(np.moveaxis(rows, 0, 1)[:n_trials])

    return TrialResult(
        survival=surv[:n_trials].astype(bool),
        densities=final_cnts[:n_trials] / p.n_cells,
        stasis_mcs=stasis[:n_trials],
        extinction_mcs=ext[:n_trials],
        mcs_completed=done,
        kept_fraction=(kept_tot / att_tot) if att_tot else 1.0,
        n_trials=n_trials,
        n_devices=n_dev,
        observables=observables,
    )
