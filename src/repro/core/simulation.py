"""MCS driver (paper Algorithms 3.3 / 3.5 / 3.6 / 3.7, unified).

The paper's lesson (maxStep, §4.2.4): keep everything device-resident and
batch many Monte-Carlo steps per launch. Here a *chunk* of ``chunk_mcs`` MCS
runs inside one jitted ``lax.scan``; the host only sees per-MCS population
counts, performs the stasis early-exit (paper §3.2.2), and fires snapshot /
checkpoint hooks between chunks.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import batched as batched_mod
from . import dominance as dom_mod
from . import lattice, metrics
from . import reference as reference_mod
from . import sublattice as sublattice_mod
from .params import EscgParams
from .rng import proposal_batch, round_shift, tile_proposal_batch


@dataclass
class SimResult:
    grid: np.ndarray               # final lattice (H, W)
    densities: np.ndarray          # (mcs_recorded + 1, S + 1), row 0 = init
    mcs_completed: int
    stasis_mcs: int                # -1 if never reached stasis
    kept_fraction: float           # applied / attempted proposals (E2 audit)


def _pick_sub_batches(n: int, want: int = 8) -> int:
    for d in (want, 4, 2, 1):
        if n % d == 0:
            return d
    return 1


def build_mcs_fn(params: EscgParams, dom: jax.Array
                 ) -> Callable[[jax.Array, jax.Array],
                               Tuple[jax.Array, jax.Array, jax.Array]]:
    """Returns one_mcs(grid, key) -> (grid, kept, attempts) for the engine."""
    p = params
    t_eps, t_eps_mu = p.action_thresholds()
    n = p.n_cells
    h, w = p.height, p.length

    if p.engine == "reference":
        def one_mcs(grid, key):
            batch = proposal_batch(key, n, n, p.neighbourhood)
            grid, kept = reference_mod.run_proposals(
                grid, batch, t_eps, t_eps_mu, dom, p.flux)
            return grid, kept, jnp.int32(n)
        return one_mcs

    if p.engine == "batched":
        n_sub = _pick_sub_batches(n)
        b_sub = n // n_sub

        def one_mcs(grid, key):
            def body(carry, k):
                g, kept = carry
                batch = proposal_batch(k, b_sub, n, p.neighbourhood)
                g, k2 = batched_mod.run_proposals(
                    g, batch, t_eps, t_eps_mu, dom, p.flux)
                return (g, kept + k2), None
            keys = jax.random.split(key, n_sub)
            (grid, kept), _ = jax.lax.scan(body, (grid, jnp.int32(0)), keys)
            return grid, kept, jnp.int32(n)
        return one_mcs

    if p.engine == "pallas_fused":
        if not p.flux:
            raise ValueError("pallas_fused requires periodic boundaries")
        th, tw = p.tile
        n_tiles = (h // th) * (w // tw)
        k_per_tile = max(1, math.ceil(n / n_tiles))
        from ..kernels import ops as kernel_ops  # lazy: avoid cycles

        def one_mcs(grid, key):
            # per-MCS Philox key = the raw PRNG key words; round_idx = 0
            seed = jax.random.key_data(key).astype(jnp.uint32)[-2:]
            shift = round_shift(jax.random.fold_in(key, 1), th, tw)
            grid = kernel_ops.escg_round_fused(
                grid, seed, jnp.uint32(0), shift, dom, p.tile, k_per_tile,
                t_eps, t_eps_mu, p.neighbourhood, roll_back=False)
            attempts = jnp.int32(n_tiles * k_per_tile)
            return grid, attempts, attempts
        return one_mcs

    if p.engine in ("sublattice", "pallas"):
        if not p.flux:
            raise ValueError("sublattice/pallas engines require flux "
                             "(periodic) boundaries; use reference/batched")
        th, tw = p.tile
        n_tiles = (h // th) * (w // tw)
        k_per_tile = max(1, math.ceil(n / n_tiles))
        interior = (th - 2) * (tw - 2)

        if p.engine == "pallas":
            from ..kernels import ops as kernel_ops  # lazy: avoid cycles
            run_round = partial(kernel_ops.escg_round, tile_shape=p.tile,
                                t_eps=t_eps, t_eps_mu=t_eps_mu,
                                roll_back=False)
        else:
            run_round = partial(sublattice_mod.run_round, tile_shape=p.tile,
                                t_eps=t_eps, t_eps_mu=t_eps_mu,
                                roll_back=False)

        # §Perf H3 iter-1: never roll back. Densities / survival statistics
        # are translation-invariant on the torus, so the lattice frame is
        # allowed to drift by the accumulated shift (composition of uniform
        # shifts stays uniform); simulate() unrolls once at the end for
        # snapshots. Halves the roll traffic per round.
        def one_mcs(grid, key):
            kp, ks = jax.random.split(key)
            props = tile_proposal_batch(kp, n_tiles, k_per_tile, interior,
                                        p.neighbourhood)
            shift = round_shift(ks, th, tw)
            grid = run_round(grid, props, shift, dom=dom)
            attempts = jnp.int32(n_tiles * k_per_tile)
            return grid, attempts, attempts
        return one_mcs

    raise ValueError(f"unknown engine {p.engine}")


def build_chunk_fn(params: EscgParams, dom: jax.Array):
    """chunk(grid, key, n_mcs<static>) -> (grid, key, counts[n,S+1], kept,
    attempts); jit-compiled, fully device-resident."""
    one_mcs = build_mcs_fn(params, dom)
    s = params.species

    @partial(jax.jit, static_argnames=("n_mcs",))
    def chunk(grid, key, n_mcs: int):
        def body(carry, _):
            g, k, kept, att = carry
            k, k1 = jax.random.split(k)
            g, k2, a2 = one_mcs(g, k1)
            cnt = metrics.counts(g, s)
            return (g, k, kept + k2, att + a2), cnt
        (grid, key, kept, att), cnts = jax.lax.scan(
            body, (grid, key, jnp.int32(0), jnp.int32(0)), length=n_mcs)
        return grid, key, cnts, kept, att

    return chunk


def simulate(params: EscgParams,
             dom: Optional[np.ndarray] = None,
             grid0: Optional[jax.Array] = None,
             key: Optional[jax.Array] = None,
             hooks: Sequence[Callable[[int, jax.Array, np.ndarray], None]] = (),
             stop_on_stasis: bool = True) -> SimResult:
    """Run the full simulation (paper Algorithm 3.3 control flow)."""
    p = params.validate()
    if dom is None:
        dom = dom_mod.circulant(p.species)
    dom_j = jnp.asarray(dom, jnp.float32)
    if key is None:
        key = jax.random.PRNGKey(p.seed)
    cell_dt = jnp.dtype(p.cell_dtype)
    if grid0 is None:
        key, k0 = jax.random.split(key)
        grid0 = lattice.init_grid(k0, p.height, p.length, p.species, p.empty,
                                  dtype=cell_dt)
    grid = jnp.asarray(grid0, cell_dt)

    chunk_fn = build_chunk_fn(p, dom_j)
    n = p.n_cells
    hist = [np.asarray(metrics.counts(grid, p.species))]
    mcs_done, stasis_mcs = 0, -1
    kept_total, att_total = 0, 0

    while mcs_done < p.mcs:
        n_mcs = min(p.chunk_mcs, p.mcs - mcs_done)
        grid, key, cnts, kept, att = chunk_fn(grid, key, n_mcs)
        cnts_h = np.asarray(cnts)
        hist.append(cnts_h)
        kept_total += int(kept)
        att_total += int(att)
        mcs_done += n_mcs
        alive = (cnts_h[:, 1:] > 0).sum(axis=1)
        if stop_on_stasis and stasis_mcs < 0 and np.any(alive <= 1):
            stasis_mcs = mcs_done - n_mcs + int(np.argmax(alive <= 1)) + 1
        for hook in hooks:
            hook(mcs_done, grid, cnts_h)
        if stop_on_stasis and stasis_mcs >= 0:
            break

    densities = np.concatenate([hist[0][None, :]] + hist[1:], axis=0) / n
    return SimResult(grid=np.asarray(grid), densities=densities,
                     mcs_completed=mcs_done, stasis_mcs=stasis_mcs,
                     kept_fraction=(kept_total / att_total) if att_total else 1.0)


# ----------------------- vmapped IID trial runner ------------------------ #

def run_trials(params: EscgParams, dom: Optional[np.ndarray], n_trials: int,
               key: Optional[jax.Array] = None,
               n_mcs: Optional[int] = None) -> np.ndarray:
    """Run ``n_trials`` IID simulations *vectorized with vmap* and return the
    final survival mask, shape (n_trials, S) bool.

    The paper runs IID trials serially (2000 runs for Park Fig 5!); batching
    trials through vmap is the single biggest beyond-paper throughput lever on
    accelerators and is what the pod axis carries at multi-pod scale.
    """
    p = params.validate()
    if dom is None:
        dom = dom_mod.circulant(p.species)
    dom_j = jnp.asarray(dom, jnp.float32)
    if key is None:
        key = jax.random.PRNGKey(p.seed)
    n_mcs = int(n_mcs if n_mcs is not None else p.mcs)
    one_mcs = build_mcs_fn(p, dom_j)

    kg, kr = jax.random.split(key)
    grids = jax.vmap(lambda k: lattice.init_grid(
        k, p.height, p.length, p.species, p.empty))(
            jax.random.split(kg, n_trials))
    keys = jax.random.split(kr, n_trials)

    @jax.jit
    def run_one(grid, key):
        def body(carry, _):
            g, k = carry
            k, k1 = jax.random.split(k)
            g, _, _ = one_mcs(g, k1)
            return (g, k), None
        (grid, _), _ = jax.lax.scan(body, (grid, key), length=n_mcs)
        return metrics.survivors(grid, p.species)

    return np.asarray(jax.vmap(run_one)(grids, keys))
