"""MCS driver (paper Algorithms 3.3 / 3.5 / 3.6 / 3.7, unified).

The paper's lesson (maxStep, §4.2.4): keep everything device-resident and
batch many Monte-Carlo steps per launch. Here a *chunk* of ``chunk_mcs`` MCS
runs inside one jitted ``lax.scan``; the host only sees per-MCS population
counts, performs the stasis early-exit (paper §3.2.2), and fires snapshot /
checkpoint hooks between chunks.

Engine selection is delegated entirely to the registry in ``engines.py``;
this module never branches on the engine name. For multi-device engines the
registry hands back a grid sharding: the lattice is placed once and the
per-MCS population counts (a ``bincount`` over the sharded lattice) lower
to per-shard partial counts plus an all-reduce, so the stasis early-exit
sees global populations without ever gathering the grid to one device.
"""
from __future__ import annotations

import json
import warnings
from dataclasses import dataclass, field
from functools import partial
from typing import Callable, Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import dominance as dom_mod
from . import engines, lattice, metrics
from . import observables as obs_mod
from .params import EscgParams
from .results import decode_observables, encode_observables

_SCENARIO_FIRST_MSG = (
    "the flat-facade call form ({fn}(params, dom, ...)) is deprecated; "
    "pass a Scenario first — {fn}(scenario, engine=EngineConfig(...), "
    "run=RunConfig(...)) — and let the registry resolve the dominance "
    "network (DESIGN.md §10/§11)")


def _resolve_call_form(fn_name, params, engine_config, run_config,
                       engine, run):
    """Scenario-first signature shim shared by ``simulate`` and
    ``trials.run_trials``: ``engine=``/``run=`` are the preferred
    spellings of ``engine_config=``/``run_config=`` (error if both are
    given), and a flat ``EscgParams`` in the scenario slot warns."""
    if engine is not None:
        if engine_config is not None:
            raise TypeError(f"{fn_name}: pass engine= or engine_config=, "
                            "not both")
        engine_config = engine
    if run is not None:
        if run_config is not None:
            raise TypeError(f"{fn_name}: pass run= or run_config=, "
                            "not both")
        run_config = run
    if isinstance(params, EscgParams):
        warnings.warn(_SCENARIO_FIRST_MSG.format(fn=fn_name),
                      DeprecationWarning, stacklevel=3)
    return engine_config, run_config


@dataclass
class SimResult:
    """Single-lattice run result (one half of the ``RunResult`` protocol,
    core/results.py; ``trials.TrialResult`` is the other).

    ``observables`` maps registered observable names to their flushed
    per-MCS streams. ``densities`` always present: shape
    ``(mcs_recorded + 1, S + 1)`` float64 with row 0 the initial lattice
    — exactly the legacy field, whether or not the device observable
    pipeline ran. Other streams (``interface_length``, ``snapshot``, ...)
    have ``post``-finalized shape ``(mcs_recorded, ...)`` with no initial
    row, appearing only when ``params.observables`` requested them.
    """
    grid: np.ndarray               # final lattice (H, W)
    observables: Dict[str, np.ndarray] = field(default_factory=dict)
    mcs_completed: int = 0
    stasis_mcs: int = -1           # -1 if never reached stasis
    kept_fraction: float = 1.0     # applied / attempted proposals (E2 audit)

    @property
    def densities(self) -> np.ndarray:
        """Deprecated alias for ``observables['densities']`` (kept for
        figure modules and goldens; prefer the observables mapping)."""
        return self.observables["densities"]

    def to_json(self) -> str:
        return json.dumps({
            "grid": np.asarray(self.grid).tolist(),
            "grid_dtype": str(np.asarray(self.grid).dtype),
            "observables": encode_observables(self.observables),
            "mcs_completed": int(self.mcs_completed),
            "stasis_mcs": int(self.stasis_mcs),
            "kept_fraction": float(self.kept_fraction),
        })

    @staticmethod
    def from_json(s: str) -> "SimResult":
        d = json.loads(s)
        return SimResult(
            grid=np.asarray(d["grid"], dtype=np.dtype(d["grid_dtype"])),
            observables=decode_observables(d["observables"]),
            mcs_completed=d["mcs_completed"],
            stasis_mcs=d["stasis_mcs"],
            kept_fraction=d["kept_fraction"])


def build_mcs_fn(params: EscgParams, dom: jax.Array):
    """one_mcs(grid, key) -> (grid, kept, attempts), resolved via the
    engine registry (back-compat shim; prefer engines.build for access to
    the grid sharding)."""
    return engines.build(params, dom).one_mcs


def build_chunk_fn(params: EscgParams, dom: jax.Array,
                   one_mcs: Optional[Callable] = None, built=None):
    """chunk(grid, key, n_mcs<static>) -> (grid, key, counts[n,S+1], kept,
    attempts); jit-compiled, fully device-resident.

    With ``params.k_mcs > 1`` (and a ``built`` engine providing
    ``multi_mcs``) the chunk runs in K-step megakernel groups — a scan of
    ``n_mcs // K`` multi-MCS launches plus one remainder launch — instead
    of one launch per MCS. Counts, key chain and trajectory are
    bit-identical to the per-MCS path (the k_mcs contract)."""
    if built is None and (one_mcs is None or params.k_mcs > 1):
        built = engines.build(params, dom)
    if one_mcs is None:
        one_mcs = built.one_mcs
    s = params.species

    if params.k_mcs > 1:
        multi = built.multi_mcs
        assert multi is not None, \
            f"engine {params.engine!r} validated k_mcs>1 but built no " \
            "multi_mcs"
        k_group = params.k_mcs

        @partial(jax.jit, static_argnames=("n_mcs",))
        def chunk(grid, key, n_mcs: int):
            q, r = divmod(n_mcs, k_group)
            kept, att = jnp.int32(0), jnp.int32(0)
            parts = []
            if q:
                def body(carry, _):
                    g, k, kept, att = carry
                    g, k, cnts, k2, a2 = multi(g, k, k_group)
                    return (g, k, kept + k2, att + a2), cnts
                (grid, key, kept, att), cnts_q = jax.lax.scan(
                    body, (grid, key, kept, att), length=q)
                parts.append(cnts_q.reshape(q * k_group, s + 1))
            if r:
                grid, key, cnts_r, k2, a2 = multi(grid, key, r)
                kept, att = kept + k2, att + a2
                parts.append(cnts_r)
            cnts = (jnp.concatenate(parts, axis=0) if parts
                    else jnp.zeros((0, s + 1), jnp.int32))
            return grid, key, cnts, kept, att

        return chunk

    @partial(jax.jit, static_argnames=("n_mcs",))
    def chunk(grid, key, n_mcs: int):
        def body(carry, _):
            g, k, kept, att = carry
            k, k1 = jax.random.split(k)
            g, k2, a2 = one_mcs(g, k1)
            cnt = metrics.counts(g, s)
            return (g, k, kept + k2, att + a2), cnt
        (grid, key, kept, att), cnts = jax.lax.scan(
            body, (grid, key, jnp.int32(0), jnp.int32(0)), length=n_mcs)
        return grid, key, cnts, kept, att

    return chunk


def build_obs_chunk_fn(params: EscgParams, dom: jax.Array, built=None):
    """Observable-pipeline chunk (DESIGN.md §11): ``chunk(grid, key, ring,
    pos, n_mcs<static>) -> (grid, key, ring, pos, kept, attempts)``.

    Returns ``(chunk, pipeline)``. Unlike :func:`build_chunk_fn` the
    per-MCS species counts never leave the device as a separate output —
    every per-MCS statistic (the ``densities`` raw-count columns included)
    is banked into the ring buffer inside the jitted chunk, and the host
    reconstructs counts from the flushed rows at chunk boundaries
    (``ObsPipeline.counts_from_rows``). The engine key chain is IDENTICAL
    to the plain chunk — ``observe`` consumes no PRNG state — so
    trajectories are bit-identical with observables on or off.

    Under ``k_mcs > 1`` grid state between megakernel launches never
    leaves the kernel: count-derived slices keep per-MCS cadence from the
    banked (K, S+1) counts, grid-derived slices are lag-held at the value
    sampled at the previous launch-group boundary (module docstring of
    core/observables.py).
    """
    if built is None:
        built = engines.build(params, dom)
    pipe = obs_mod.build_pipeline(params)
    observe = built.observe or pipe.row
    s = params.species

    if params.k_mcs > 1:
        multi = built.multi_mcs
        assert multi is not None, \
            f"engine {params.engine!r} validated k_mcs>1 but built no " \
            "multi_mcs"
        k_group = params.k_mcs

        if built.grid_sharding is not None:
            # pin held values replicated across the grid mesh — same
            # check_rep=False partitioner hazard as the generic observe
            # hook wrap in engines.build (ring rows otherwise get summed
            # across a mesh axis)
            _rep = jax.sharding.NamedSharding(
                built.grid_sharding.mesh, jax.sharding.PartitionSpec())

            def grid_vals(grid):
                return {k: jax.lax.with_sharding_constraint(v, _rep)
                        for k, v in pipe.grid_values(grid).items()}
        else:
            grid_vals = pipe.grid_values

        @partial(jax.jit, static_argnames=("n_mcs",))
        def chunk(grid, key, ring, pos, n_mcs: int):
            kept, att = jnp.int32(0), jnp.int32(0)
            held = grid_vals(grid)   # lag-hold state (group boundary)

            def launch(grid, key, ring, pos, kept, att, held, k_steps):
                grid, key, cnts, k2, a2 = multi(grid, key, k_steps)
                rows = jax.vmap(lambda c: pipe.row_held(c, held))(cnts)
                ring, pos = obs_mod.ring_push_many(ring, pos, rows)
                held = grid_vals(grid)
                return grid, key, ring, pos, kept + k2, att + a2, held

            q, r = divmod(n_mcs, k_group)
            if q:
                def body(carry, _):
                    return launch(*carry, k_group), None
                (grid, key, ring, pos, kept, att, held), _ = jax.lax.scan(
                    body, (grid, key, ring, pos, kept, att, held), length=q)
            if r:
                grid, key, ring, pos, kept, att, held = launch(
                    grid, key, ring, pos, kept, att, held, r)
            return grid, key, ring, pos, kept, att

        return chunk, pipe

    one_mcs = built.one_mcs

    @partial(jax.jit, static_argnames=("n_mcs",))
    def chunk(grid, key, ring, pos, n_mcs: int):
        def body(carry, _):
            g, k, ring, pos, kept, att = carry
            k, k1 = jax.random.split(k)
            g, k2, a2 = one_mcs(g, k1)
            cnt = metrics.counts(g, s)
            ring, pos = obs_mod.ring_push(ring, pos, observe(g, cnt))
            return (g, k, ring, pos, kept + k2, att + a2), None
        (grid, key, ring, pos, kept, att), _ = jax.lax.scan(
            body, (grid, key, ring, pos, jnp.int32(0), jnp.int32(0)),
            length=n_mcs)
        return grid, key, ring, pos, kept, att

    return chunk, pipe


def simulate(params: EscgParams,
             dom: Optional[np.ndarray] = None,
             grid0: Optional[jax.Array] = None,
             key: Optional[jax.Array] = None,
             hooks: Sequence[Callable[[int, jax.Array, np.ndarray], None]] = (),
             stop_on_stasis: bool = True,
             engine_config=None, run_config=None, *,
             engine=None, run=None) -> SimResult:
    """Run the full simulation (paper Algorithm 3.3 control flow).

    Scenario-first signature: ``simulate(scenario, engine=EngineConfig(...),
    run=RunConfig(...))`` — the primary positional argument is a
    ``Scenario`` (DESIGN.md §10); ``dom=None`` derives the dominance
    network from the scenario registry, and the scenario's declared
    observables stream through the device ring buffer (DESIGN.md §11)
    unless ``run.observables`` pins the set. The legacy flat form
    ``simulate(params, dom, ...)`` still works behind a
    ``DeprecationWarning`` (``engine_config=``/``run_config=`` are the
    equally-deprecated spellings of ``engine=``/``run=``).

    Chunked stasis early-exit semantics (paper §3.2.2): each jitted chunk
    returns per-MCS population counts; the host scans them for the first
    MCS with <= 1 species alive. ``stasis_mcs`` is therefore exact to the
    MCS, but the run only *stops* at the next chunk boundary — up to
    ``chunk_mcs - 1`` extra MCS execute after stasis (their counts are
    still recorded in ``densities``). Hooks fire once per chunk, including
    the chunk in which stasis was detected. The trial-batch counterpart
    (``trials.run_trials``) applies the same rule per trial and exits only
    when every trial has reached stasis.

    With ``params.observables`` non-empty every per-MCS statistic —
    including the species counts the stasis early-exit and hooks consume —
    is banked on device into the observable ring buffer and flushed ONCE
    per chunk; there is no separate per-MCS counts transfer (the
    ``print_frequency`` density path reads the same flushed rows). The
    ring must hold a full chunk (``obs_capacity`` >= effective chunk, or
    0 = auto-size to one chunk).
    """
    from .scenarios import resolve_config  # lazy: scenarios imports core
    engine_config, run_config = _resolve_call_form(
        "simulate", params, engine_config, run_config, engine, run)
    params, dom = resolve_config(params, dom, engine_config, run_config)
    p = params.validate()
    if dom is None:
        dom = dom_mod.circulant(p.species)
    dom_j = jnp.asarray(dom, jnp.float32)
    if key is None:
        key = jax.random.PRNGKey(p.seed)
    cell_dt = jnp.dtype(p.cell_dtype)
    if grid0 is None:
        key, k0 = jax.random.split(key)
        grid0 = lattice.init_grid(k0, p.height, p.length, p.species, p.empty,
                                  dtype=cell_dt)
    grid = jnp.asarray(grid0, cell_dt)

    eng = engines.build(p, dom_j)
    if eng.grid_sharding is not None:
        grid = jax.device_put(grid, eng.grid_sharding)
    n = p.n_cells
    obs_on = bool(p.observables)
    pipe, ring, pos, rows_all = None, None, None, []
    if obs_on:
        chunk_fn, pipe = build_obs_chunk_fn(p, dom_j, built=eng)
        max_chunk = max(1, min(p.chunk_mcs, p.mcs))
        cap = obs_mod.ring_capacity(p, max_chunk)
        if cap < max_chunk:
            raise ValueError(
                f"obs_capacity {cap} < chunk rows {max_chunk}: simulate "
                "flushes the ring once per chunk and its stasis accounting "
                "reads every row, so the ring must hold a full chunk "
                "(0 = auto-size)")
        ring, pos = obs_mod.ring_init(cap, (pipe.width,))
    else:
        chunk_fn = build_chunk_fn(p, dom_j, built=eng)
    hist = [np.asarray(metrics.counts(grid, p.species))]
    mcs_done, stasis_mcs = 0, -1
    kept_total, att_total = 0, 0

    while mcs_done < p.mcs:
        n_mcs = min(p.chunk_mcs, p.mcs - mcs_done)
        if obs_on:
            grid, key, ring, pos, kept, att = chunk_fn(grid, key, ring, pos,
                                                       n_mcs)
            # ONE device->host transfer per chunk: the flushed ring rows
            # carry every per-MCS statistic, counts included
            rows_h = obs_mod.ring_flush(np.asarray(ring), mcs_done,
                                        mcs_done + n_mcs)
            rows_all.append(rows_h)
            cnts_h = pipe.counts_from_rows(rows_h, p.species)
        else:
            grid, key, cnts, kept, att = chunk_fn(grid, key, n_mcs)
            cnts_h = np.asarray(cnts)
        hist.append(cnts_h)
        kept_total += int(kept)
        att_total += int(att)
        mcs_done += n_mcs
        alive = (cnts_h[:, 1:] > 0).sum(axis=1)
        if stop_on_stasis and stasis_mcs < 0 and np.any(alive <= 1):
            stasis_mcs = mcs_done - n_mcs + int(np.argmax(alive <= 1)) + 1
        for hook in hooks:
            hook(mcs_done, grid, cnts_h)
        if stop_on_stasis and stasis_mcs >= 0:
            break

    densities = np.concatenate([hist[0][None, :]] + hist[1:], axis=0) / n
    observables = {"densities": densities}
    if obs_on and rows_all:
        streams = pipe.split(np.concatenate(rows_all, axis=0))
        streams["densities"] = densities  # legacy shape: initial row kept
        observables = streams
    return SimResult(grid=np.asarray(grid), observables=observables,
                     mcs_completed=mcs_done, stasis_mcs=stasis_mcs,
                     kept_fraction=(kept_total / att_total) if att_total else 1.0)


# ----------------------- vmapped IID trial runner ------------------------ #

def run_trials(params: EscgParams, dom: Optional[np.ndarray], n_trials: int,
               key: Optional[jax.Array] = None,
               n_mcs: Optional[int] = None) -> np.ndarray:
    """Back-compat wrapper over the trial subsystem (``core.trials``):
    returns only the final survival mask, shape (n_trials, S) bool.

    The full driver — chunked, device-sharded over the pod axis, streaming
    stasis / extinction statistics — lives in ``trials.run_trials`` and
    returns a ``TrialResult``; prefer it for new code (DESIGN.md §4). The
    trial driver honours ``params.cell_dtype`` (the legacy vmap runner here
    silently initialized int32 lattices regardless).
    """
    from .trials import run_trials as _run_trials  # lazy: avoid cycle
    return _run_trials(params, dom, n_trials, key=key, n_mcs=n_mcs,
                       stop_on_stasis=False).survival
