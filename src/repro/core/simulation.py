"""MCS driver (paper Algorithms 3.3 / 3.5 / 3.6 / 3.7, unified).

The paper's lesson (maxStep, §4.2.4): keep everything device-resident and
batch many Monte-Carlo steps per launch. Here a *chunk* of ``chunk_mcs`` MCS
runs inside one jitted ``lax.scan``; the host only sees per-MCS population
counts, performs the stasis early-exit (paper §3.2.2), and fires snapshot /
checkpoint hooks between chunks.

Engine selection is delegated entirely to the registry in ``engines.py``;
this module never branches on the engine name. For multi-device engines the
registry hands back a grid sharding: the lattice is placed once and the
per-MCS population counts (a ``bincount`` over the sharded lattice) lower
to per-shard partial counts plus an all-reduce, so the stasis early-exit
sees global populations without ever gathering the grid to one device.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import dominance as dom_mod
from . import engines, lattice, metrics
from .params import EscgParams


@dataclass
class SimResult:
    grid: np.ndarray               # final lattice (H, W)
    densities: np.ndarray          # (mcs_recorded + 1, S + 1), row 0 = init
    mcs_completed: int
    stasis_mcs: int                # -1 if never reached stasis
    kept_fraction: float           # applied / attempted proposals (E2 audit)


def build_mcs_fn(params: EscgParams, dom: jax.Array):
    """one_mcs(grid, key) -> (grid, kept, attempts), resolved via the
    engine registry (back-compat shim; prefer engines.build for access to
    the grid sharding)."""
    return engines.build(params, dom).one_mcs


def build_chunk_fn(params: EscgParams, dom: jax.Array,
                   one_mcs: Optional[Callable] = None, built=None):
    """chunk(grid, key, n_mcs<static>) -> (grid, key, counts[n,S+1], kept,
    attempts); jit-compiled, fully device-resident.

    With ``params.k_mcs > 1`` (and a ``built`` engine providing
    ``multi_mcs``) the chunk runs in K-step megakernel groups — a scan of
    ``n_mcs // K`` multi-MCS launches plus one remainder launch — instead
    of one launch per MCS. Counts, key chain and trajectory are
    bit-identical to the per-MCS path (the k_mcs contract)."""
    if built is None and (one_mcs is None or params.k_mcs > 1):
        built = engines.build(params, dom)
    if one_mcs is None:
        one_mcs = built.one_mcs
    s = params.species

    if params.k_mcs > 1:
        multi = built.multi_mcs
        assert multi is not None, \
            f"engine {params.engine!r} validated k_mcs>1 but built no " \
            "multi_mcs"
        k_group = params.k_mcs

        @partial(jax.jit, static_argnames=("n_mcs",))
        def chunk(grid, key, n_mcs: int):
            q, r = divmod(n_mcs, k_group)
            kept, att = jnp.int32(0), jnp.int32(0)
            parts = []
            if q:
                def body(carry, _):
                    g, k, kept, att = carry
                    g, k, cnts, k2, a2 = multi(g, k, k_group)
                    return (g, k, kept + k2, att + a2), cnts
                (grid, key, kept, att), cnts_q = jax.lax.scan(
                    body, (grid, key, kept, att), length=q)
                parts.append(cnts_q.reshape(q * k_group, s + 1))
            if r:
                grid, key, cnts_r, k2, a2 = multi(grid, key, r)
                kept, att = kept + k2, att + a2
                parts.append(cnts_r)
            cnts = (jnp.concatenate(parts, axis=0) if parts
                    else jnp.zeros((0, s + 1), jnp.int32))
            return grid, key, cnts, kept, att

        return chunk

    @partial(jax.jit, static_argnames=("n_mcs",))
    def chunk(grid, key, n_mcs: int):
        def body(carry, _):
            g, k, kept, att = carry
            k, k1 = jax.random.split(k)
            g, k2, a2 = one_mcs(g, k1)
            cnt = metrics.counts(g, s)
            return (g, k, kept + k2, att + a2), cnt
        (grid, key, kept, att), cnts = jax.lax.scan(
            body, (grid, key, jnp.int32(0), jnp.int32(0)), length=n_mcs)
        return grid, key, cnts, kept, att

    return chunk


def simulate(params: EscgParams,
             dom: Optional[np.ndarray] = None,
             grid0: Optional[jax.Array] = None,
             key: Optional[jax.Array] = None,
             hooks: Sequence[Callable[[int, jax.Array, np.ndarray], None]] = (),
             stop_on_stasis: bool = True,
             engine_config=None, run_config=None) -> SimResult:
    """Run the full simulation (paper Algorithm 3.3 control flow).

    ``params`` is either the legacy flat ``EscgParams`` or a ``Scenario``
    from the scenario layer (DESIGN.md §10) — with a ``Scenario``, pass
    ``engine_config`` / ``run_config`` to pick the engine and run control,
    and ``dom=None`` derives the dominance network from the scenario
    registry instead of the circulant default.

    Chunked stasis early-exit semantics (paper §3.2.2): each jitted chunk
    returns per-MCS population counts; the host scans them for the first
    MCS with <= 1 species alive. ``stasis_mcs`` is therefore exact to the
    MCS, but the run only *stops* at the next chunk boundary — up to
    ``chunk_mcs - 1`` extra MCS execute after stasis (their counts are
    still recorded in ``densities``). Hooks fire once per chunk, including
    the chunk in which stasis was detected. The trial-batch counterpart
    (``trials.run_trials``) applies the same rule per trial and exits only
    when every trial has reached stasis.
    """
    from .scenarios import resolve_config  # lazy: scenarios imports core
    params, dom = resolve_config(params, dom, engine_config, run_config)
    p = params.validate()
    if dom is None:
        dom = dom_mod.circulant(p.species)
    dom_j = jnp.asarray(dom, jnp.float32)
    if key is None:
        key = jax.random.PRNGKey(p.seed)
    cell_dt = jnp.dtype(p.cell_dtype)
    if grid0 is None:
        key, k0 = jax.random.split(key)
        grid0 = lattice.init_grid(k0, p.height, p.length, p.species, p.empty,
                                  dtype=cell_dt)
    grid = jnp.asarray(grid0, cell_dt)

    eng = engines.build(p, dom_j)
    if eng.grid_sharding is not None:
        grid = jax.device_put(grid, eng.grid_sharding)
    chunk_fn = build_chunk_fn(p, dom_j, built=eng)
    n = p.n_cells
    hist = [np.asarray(metrics.counts(grid, p.species))]
    mcs_done, stasis_mcs = 0, -1
    kept_total, att_total = 0, 0

    while mcs_done < p.mcs:
        n_mcs = min(p.chunk_mcs, p.mcs - mcs_done)
        grid, key, cnts, kept, att = chunk_fn(grid, key, n_mcs)
        cnts_h = np.asarray(cnts)
        hist.append(cnts_h)
        kept_total += int(kept)
        att_total += int(att)
        mcs_done += n_mcs
        alive = (cnts_h[:, 1:] > 0).sum(axis=1)
        if stop_on_stasis and stasis_mcs < 0 and np.any(alive <= 1):
            stasis_mcs = mcs_done - n_mcs + int(np.argmax(alive <= 1)) + 1
        for hook in hooks:
            hook(mcs_done, grid, cnts_h)
        if stop_on_stasis and stasis_mcs >= 0:
            break

    densities = np.concatenate([hist[0][None, :]] + hist[1:], axis=0) / n
    return SimResult(grid=np.asarray(grid), densities=densities,
                     mcs_completed=mcs_done, stasis_mcs=stasis_mcs,
                     kept_fraction=(kept_total / att_total) if att_total else 1.0)


# ----------------------- vmapped IID trial runner ------------------------ #

def run_trials(params: EscgParams, dom: Optional[np.ndarray], n_trials: int,
               key: Optional[jax.Array] = None,
               n_mcs: Optional[int] = None) -> np.ndarray:
    """Back-compat wrapper over the trial subsystem (``core.trials``):
    returns only the final survival mask, shape (n_trials, S) bool.

    The full driver — chunked, device-sharded over the pod axis, streaming
    stasis / extinction statistics — lives in ``trials.run_trials`` and
    returns a ``TrialResult``; prefer it for new code (DESIGN.md §4). The
    trial driver honours ``params.cell_dtype`` (the legacy vmap runner here
    silently initialized int32 lattices regardless).
    """
    from .trials import run_trials as _run_trials  # lazy: avoid cycle
    return _run_trials(params, dom, n_trials, key=key, n_mcs=n_mcs,
                       stop_on_stasis=False).survival
