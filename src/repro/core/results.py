"""Unified run-result protocol (DESIGN.md §11).

``simulate`` returns a ``SimResult`` and ``trials.run_trials`` a
``TrialResult``; both now satisfy one structural :class:`RunResult`
protocol — a common ``observables`` mapping fed by the device ring-buffer
flush, plus ``to_json``/``from_json`` round-trips — so the serving layer
and figure modules can consume either without caring which driver
produced it. The legacy attribute surface (``densities`` et al.) stays
as deprecated aliases on the concrete classes.
"""
from __future__ import annotations

from typing import Dict, Mapping, Protocol, runtime_checkable

import numpy as np

__all__ = ["RunResult", "encode_observables", "decode_observables"]


@runtime_checkable
class RunResult(Protocol):
    """Structural contract shared by SimResult and TrialResult.

    ``observables`` maps registered observable names (core/observables.py)
    to host arrays flushed from the device ring buffer; every result also
    reports how many MCS actually ran and serializes losslessly.
    """

    @property
    def observables(self) -> Mapping[str, np.ndarray]: ...

    @property
    def mcs_completed(self) -> int: ...

    def to_json(self) -> str: ...


def encode_observables(obs: Mapping[str, np.ndarray]) -> Dict[str, dict]:
    """JSON-encodable payload for an observables mapping: dtype + shape +
    flat data per stream (float64/int arrays round-trip exactly)."""
    out = {}
    for name, arr in obs.items():
        a = np.asarray(arr)
        out[name] = {"dtype": str(a.dtype), "shape": list(a.shape),
                     "data": a.reshape(-1).tolist()}
    return out


def decode_observables(payload: Mapping[str, dict]) -> Dict[str, np.ndarray]:
    """Inverse of :func:`encode_observables`."""
    return {name: np.asarray(d["data"], dtype=np.dtype(d["dtype"]))
            .reshape(tuple(d["shape"]))
            for name, d in payload.items()}
