"""E2: the batched "maxStep" engine — the faithful TPU port of the paper's
atomics-arbitrated parallel elementary steps (paper §2.6, §3.2.2, §3.3).

CUDA resolves contested cells with hardware atomics ("only one write will
successfully complete for each contested memory address"); TPUs have no
atomics, so we arbitrate identically but deterministically with a
**scatter-min of proposal index over both touched cells**: the earliest
proposal touching a cell wins it; a proposal survives only if it won *both*
its cells. Survivors are provably pairwise disjoint and are applied with one
masked scatter. Losers are dropped — the same fate the paper assigns to
overwritten atomic updates — and the drop count is reported so MCS accounting
can be audited (paper counts every attempt; so do we).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from . import lattice
from .rng import ProposalBatch
from .rules import apply_pair


def run_proposals(grid: jax.Array, batch: ProposalBatch, t_eps: float,
                  t_eps_mu: float, dom: jax.Array, flux: bool = True
                  ) -> Tuple[jax.Array, jax.Array]:
    """Apply one arbitration window of proposals in parallel.

    Returns (grid, n_kept). Bit-identical to
    ``reference.run_proposals(..., drop_conflicts=True)``.
    """
    h, w = grid.shape
    n = h * w
    g = grid.reshape(-1)
    i = batch.cell
    ni = lattice.neighbor_index(batch.cell, batch.dirn, h, w, flux)
    b = i.shape[0]
    order = jnp.arange(b, dtype=jnp.int32)

    # --- arbitration: first proposal to touch a cell owns it ---
    winner = jnp.full((n,), b, dtype=jnp.int32)
    winner = winner.at[i].min(order)
    winner = winner.at[ni].min(order)
    keep = (winner[i] == order) & (winner[ni] == order)

    # --- rule application on the ORIGINAL grid (survivors are disjoint) ---
    s = g[i]
    nb = g[ni]
    ns, nn = apply_pair(s, nb, batch.u_act, batch.u_dom, t_eps, t_eps_mu, dom)

    # --- masked scatter: dropped proposals write to a shadow slot ---
    gpad = jnp.concatenate([g, jnp.zeros((1,), g.dtype)])
    ti = jnp.where(keep, i, n)
    tn = jnp.where(keep, ni, n)
    gpad = gpad.at[ti].set(jnp.where(keep, ns, 0))
    gpad = gpad.at[tn].set(jnp.where(keep, nn, 0))
    return gpad[:n].reshape(h, w), jnp.sum(keep.astype(jnp.int32))
