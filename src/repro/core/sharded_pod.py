"""Composed pod x grid mesh engine: IID trials x domain decomposition
(DESIGN.md §6; the ROADMAP "compose the two axes" north-star item).

PR 1 scaled the grid axis (one big lattice over ('rows','cols'), halo
exchange) and PR 2 scaled the trial axis (many IID lattices over a 1-D
'pod' mesh); this module composes them on a single
``('pod', 'rows', 'cols')`` device mesh — the regime the paper's
replication studies actually need (many IID trials x large grids; sPEGG
and BioDynaMo both run the replicate axis and the spatial domain on the
accelerator simultaneously).

Layout: a batch of trial lattices, shape (n_trials, H, W), shards as
``P('pod', 'rows', 'cols')`` — pod group ``g`` owns ``n_trials / P``
whole replicates, and within the group each replicate is domain-decomposed
exactly like the ``sharded`` engine. One MCS runs inside one ``shard_map``
region over all three axes: the per-trial local round (halo exchange +
per-tile Philox sweeps, ``core.sharded``) is ``jax.vmap``-ed over the
local trial slice. ppermute/axis_index batch cleanly under vmap, and the
pod axis needs no collectives at all (IID trials never interact).

**Bit-identity for every factorization.** Both axes key by stable global
identity (DESIGN.md §3): trial ``t`` is keyed by ``fold_in(base, t)`` and
tile ``i`` of trial ``t`` by ``fold_in(round key, global tile id)`` —
never by pod width, shard layout, or padding. A ``(P, R, C)`` run is
therefore bit-identical to the ``(1, 1, 1)`` layout, which in turn is
bit-identical to the single-device ``sublattice`` engine
(tests/test_properties.py asserts this for every factorization of 8 fake
devices).

The in-region tile sweeps honour ``params.local_kernel``: 'jnp' and
'pallas' run the same VMEM-tiled paths as the single-device engines
(oracle: ``sublattice``), and 'fused' derives proposals in-kernel from
Philox counters keyed by global (tile, trial) identity — zero proposal
arrays in HBM, bit-identical to the single-device ``pallas_fused`` engine
for every mesh factorization (oracle family two; DESIGN.md §6).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from .engines import BuiltEngine, _tiled_setup, multi_round_inputs
from .sharded import (build_engine as build_grid_engine, make_local_round,
                      make_local_multi_round, round_stream_inputs)

POD_AXIS, ROW_AXIS, COL_AXIS = "pod", "rows", "cols"


def build_engine(params, dom: jax.Array,
                 mesh: Optional[Mesh] = None) -> BuiltEngine:
    """Registry builder for engine='sharded_pod'.

    ``mesh`` defaults to ``parallel.sharding.pod_lattice_mesh`` shaped by
    ``params.mesh_shape`` (all local devices on the pod axis when None).
    Returns a BuiltEngine carrying BOTH contracts: ``one_mcs`` advances a
    single lattice on the ('rows','cols') sub-mesh of pod group 0 (so
    ``simulate`` works unchanged), and ``one_mcs_batch`` advances a whole
    trial batch on the full composed mesh (consumed by
    ``trials.run_trials``; see DESIGN.md §6).
    """
    from ..parallel.sharding import pod_lattice_mesh  # lazy: parallel->models

    p = params.validate()
    th, tw, n_tiles, k_per, _ = _tiled_setup(p)

    if mesh is None:
        mesh = pod_lattice_mesh(p.mesh_shape, p.height, p.length, th, tw)
    pw = mesh.shape[POD_AXIS]
    dr, dc = mesh.shape[ROW_AXIS], mesh.shape[COL_AXIS]
    if (p.height // dr) % th or (p.length // dc) % tw:
        raise ValueError(
            f"device blocks ({p.height // dr}x{p.length // dc}) must be "
            f"unions of {th}x{tw} tiles")

    # single-lattice path (simulate): the grid axes of pod group 0
    sub = build_grid_engine(p, dom, mesh=Mesh(mesh.devices[0],
                                              (ROW_AXIS, COL_AXIS)))

    batch_spec = P(POD_AXIS, ROW_AXIS, COL_AXIS)
    pod_spec = P(POD_AXIS)

    # THE per-block round the sharded engine runs (one shared definition,
    # core.sharded.make_local_round), vmapped over the local trial slice
    local_round = make_local_round(p, dom, (dr, dc), ROW_AXIS, COL_AXIS)

    round_fn = shard_map(
        lambda gs, kps, shifts: jax.vmap(local_round)(gs, kps, shifts),
        mesh=mesh, in_specs=(batch_spec, pod_spec, pod_spec),
        out_specs=batch_spec, check_rep=False)

    def one_mcs_batch(grids, keys):
        """Advance every trial one MCS. ``grids``: (n, H, W) on
        ``batch_sharding``; ``keys``: (n, 2) per-trial keys on
        ``key_sharding``. Per-trial key usage matches the single-lattice
        engine of the same local-kernel family exactly
        (``sharded.round_stream_inputs``: split -> proposal/shift keys for
        jnp/pallas, the pallas_fused Philox-seed schedule for 'fused'), so
        trial t's trajectory is bit-identical to running it alone."""
        streams, shifts = jax.vmap(
            lambda k: round_stream_inputs(p, k, th, tw))(keys)
        grids = round_fn(grids, streams, shifts)
        att = jnp.full((grids.shape[0],), n_tiles * k_per, jnp.int32)
        return grids, att, att

    multi_mcs_batch = None
    if p.local_kernel == "fused":
        # k_mcs megakernel over the composed mesh: the per-block K-step
        # local multi-round (core.sharded.make_local_multi_round — the
        # TRUE megakernel when (dr, dc) == (1, 1)) vmapped over each pod
        # group's trial slice; per-step counts come back per trial
        multi_fns = {}

        def _multi_fn(k_steps: int):
            if k_steps not in multi_fns:
                local_multi = make_local_multi_round(
                    p, dom, (dr, dc), k_steps, ROW_AXIS, COL_AXIS)
                multi_fns[k_steps] = shard_map(
                    lambda gs, seeds, shifts:
                        jax.vmap(local_multi)(gs, seeds, shifts),
                    mesh=mesh, in_specs=(batch_spec, pod_spec, pod_spec),
                    out_specs=(batch_spec, pod_spec), check_rep=False)
            return multi_fns[k_steps]

        def multi_mcs_batch(grids, keys, k_steps):
            """K MCS for every trial in one region: per-trial K-step fused
            schedules (bit-identical key chain to K one_mcs_batch calls),
            counts (n, K, species + 1)."""
            keys, seeds, shifts = jax.vmap(
                lambda k: multi_round_inputs(k, th, tw, k_steps))(keys)
            grids, counts = _multi_fn(k_steps)(grids, seeds, shifts)
            att = jnp.full((grids.shape[0],), k_steps * n_tiles * k_per,
                           jnp.int32)
            return grids, keys, counts, att, att

    return BuiltEngine(
        one_mcs=sub.one_mcs,
        grid_sharding=sub.grid_sharding,
        one_mcs_batch=one_mcs_batch,
        batch_sharding=NamedSharding(mesh, batch_spec),
        key_sharding=NamedSharding(mesh, pod_spec),
        pod_width=pw,
        multi_mcs=sub.multi_mcs,
        multi_mcs_batch=multi_mcs_batch,
    )
