from . import roofline, sharding
