"""Logical-axis -> mesh-axis sharding rules (DESIGN.md §5).

One rules table drives params, optimizer state, caches and activations:
  * TP: q_heads / kv_heads / ffn / vocab / experts / mamba-inner -> 'model'
  * FSDP (ZeRO-3): the 'embed' axis of weights -> 'data' (XLA all-gathers
    per layer inside the scan, reduce-scatters grads)
  * DP: 'batch' -> ('pod', 'data') on the multi-pod mesh
  * SP: 'kv_seq' -> 'data' for single-sequence long-context decode
Head counts not divisible by the model axis use GSPMD padding (visible in the
roofline useful-FLOPs ratio; a hillclimb lever).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from ..models import spec as spec_mod

# Default logical-axis rules (mesh axes: pod?, data, model).
DEFAULT_RULES: Dict[str, Optional[Any]] = {
    # weights
    "embed": "data",            # FSDP shard of the model dim
    "vocab": "model",
    "q_heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "ffn": "model",
    "experts": "model",         # EP
    "experts_r": None,          # router output dim (small)
    "expert_ffn": None,
    "layers": None,             # scanned; never sharded
    # mamba
    "inner": "model",
    "inner2": "model",
    "inner_zxbcdt": "model",
    "dbc": None,
    "dt_rank": None,
    "state": None,
    "conv": None,
    "heads": "model",
    # activations / caches
    "batch": ("pod", "data"),
    "seq": None,
    "kv_seq": None,
    "act_batch": ("pod", "data"),   # activation constraints (ctx.constrain)
    "act_seq": "model",             # Megatron-style sequence parallelism
}


def make_rules(mesh: Mesh, overrides: Optional[Dict[str, Any]] = None,
               shape_kind: str = "train",
               global_batch: Optional[int] = None) -> Dict[str, Any]:
    rules = dict(DEFAULT_RULES)
    axes = mesh.axis_names
    if "pod" not in axes:
        rules["batch"] = ("data",)
        rules["act_batch"] = ("data",)
    else:
        # multi-pod: ZeRO-3 over pod x data — params/opt-state/grads shard
        # over both (the 1T MoE needs 512-way weight sharding: params+grads
        # alone exceed a 16 GB chip at 256-way). The per-layer all-gather
        # over 'pod' crosses the DCN but overlaps with layer compute.
        rules["embed"] = ("data", "pod")
    if shape_kind == "decode":
        # KV caches: kv-head counts (4-8) rarely divide the 16-way model
        # axis, so shard the cache SEQUENCE over 'model' instead
        # (flash-decoding: per-shard partial attention + online-softmax
        # combine, which GSPMD emits as small all-reduces of (B,H,1) stats).
        rules["kv_seq"] = "model"
    if global_batch is not None:
        # single-sequence long-context decode: batch unshardable -> sequence
        # parallelism over BOTH axes
        dp = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
        if global_batch < dp:
            rules["batch"] = None
            rules["kv_seq"] = ("data", "model")
    if overrides:
        rules.update(overrides)
    return rules


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def fit_spec(shape, pspec: P, mesh: Mesh) -> P:
    """Drop shardings whose axis size does not divide the dimension (e.g.
    24 q-heads or a 51865 vocab on a 16-way model axis) — the standard
    logical-rules fallback. jit in_shardings require exact divisibility;
    configs pad hot dims (vocab) so the fallback stays rare."""
    out = []
    used = set()
    for dim, axis in zip(shape, tuple(pspec) + (None,) * (len(shape)
                                                          - len(pspec))):
        if axis is not None and dim % _axis_size(mesh, axis) != 0:
            axis = None
        names = (axis if isinstance(axis, (tuple, list))
                 else (axis,) if axis else ())
        if any(n in used for n in names):      # each mesh axis used once
            axis = None
        else:
            used.update(names)
        out.append(axis)
    return P(*out)


def named_sharding_tree(spec_tree, mesh: Mesh, rules: Dict[str, Any]):
    """ParamSpec tree -> NamedSharding tree (validated against the mesh)."""
    pspecs = spec_mod.partition_tree(spec_tree, rules)

    def build(s, ps):
        return NamedSharding(mesh, fit_spec(s.shape, ps, mesh))
    return jax.tree.map(build, spec_tree, pspecs,
                        is_leaf=lambda x: isinstance(x, (P,
                                                         spec_mod.ParamSpec)))


def batch_sharding(mesh: Mesh, rules: Dict[str, Any]):
    """Shardings for input batches: leading dim = batch, rest replicated."""
    b = rules.get("batch")

    def shard_for(ndim: int):
        return NamedSharding(mesh, P(*((b,) + (None,) * (ndim - 1))))
    return shard_for


def scalar_sharding(mesh: Mesh):
    return NamedSharding(mesh, P())


# ------------------- ESCG lattice domain decomposition -------------------- #

def auto_shard_grid(n_devices: int, height: int, width: int,
                    tile_h: int, tile_w: int) -> tuple:
    """Pick a (rows, cols) device grid for the sharded ESCG engine.

    Constraints: every device block must be a union of (tile_h, tile_w)
    tiles, i.e. rows | height, cols | width, and the per-device block must
    be a tile multiple. Among factorizations of d = n_devices, n_devices-1,
    ... the first feasible d wins (use as many devices as the lattice
    admits) and within it the most square-ish split (minimal perimeter =
    minimal halo traffic)."""
    def feasible(dr, dc):
        return (height % dr == 0 and (height // dr) % tile_h == 0
                and width % dc == 0 and (width // dc) % tile_w == 0)

    for d in range(n_devices, 0, -1):
        pairs = [(dr, d // dr) for dr in range(1, d + 1) if d % dr == 0]
        pairs = [pq for pq in pairs if feasible(*pq)]
        if pairs:
            return min(pairs, key=lambda pq: abs(pq[0] - pq[1]))
    return (1, 1)


def pod_lattice_mesh(mesh_shape, height: int, width: int,
                     tile_h: int, tile_w: int, pod_axis: str = "pod",
                     row_axis: str = "rows", col_axis: str = "cols",
                     devices=None) -> Mesh:
    """Composed ``('pod', 'rows', 'cols')`` mesh for the sharded_pod
    engine (DESIGN.md §6): the trial axis shards over ``pod`` while each
    trial's lattice domain-decomposes over ``(rows, cols)``.

    ``mesh_shape=None`` puts every local device on the pod axis —
    replication throughput is the common regime, and a ``(D, 1, 1)``
    layout needs no halo traffic at all. Pass an explicit ``(P, R, C)``
    to spend devices on the grid axes instead (lattices too big for one
    device's memory). The (rows, cols) factors obey the same constraint
    as the sharded engine: every device block must be a union of
    (tile_h, tile_w) tiles."""
    import numpy as np

    if devices is None:
        devices = jax.devices()
    if mesh_shape is None:
        mesh_shape = (len(devices), 1, 1)
    pp, dr, dc = mesh_shape
    if pp < 1 or dr < 1 or dc < 1:
        raise ValueError(f"mesh_shape dims must be >= 1, got {mesh_shape}")
    if pp * dr * dc > len(devices):
        raise ValueError(f"mesh_shape {tuple(mesh_shape)} needs "
                         f"{pp * dr * dc} devices; only {len(devices)} "
                         "available")
    if height % dr or (height // dr) % tile_h:
        raise ValueError(f"rows={dr} must split height={height} into "
                         f"multiples of tile_h={tile_h}")
    if width % dc or (width // dc) % tile_w:
        raise ValueError(f"cols={dc} must split width={width} into "
                         f"multiples of tile_w={tile_w}")
    dev = np.asarray(devices[:pp * dr * dc]).reshape(pp, dr, dc)
    return Mesh(dev, (pod_axis, row_axis, col_axis))


def lattice_mesh(shard_grid, height: int, width: int,
                 tile_h: int, tile_w: int, row_axis: str = "rows",
                 col_axis: str = "cols", devices=None) -> Mesh:
    """Mesh over the 2-D lattice decomposition. ``shard_grid=None`` picks
    the largest feasible device grid automatically (possibly leaving
    devices idle when the lattice doesn't factor)."""
    import numpy as np

    if devices is None:
        devices = jax.devices()
    if shard_grid is None:
        shard_grid = auto_shard_grid(len(devices), height, width,
                                     tile_h, tile_w)
    dr, dc = shard_grid
    if dr * dc > len(devices):
        raise ValueError(f"shard_grid {shard_grid} needs {dr * dc} devices; "
                         f"only {len(devices)} available")
    dev = np.asarray(devices[:dr * dc]).reshape(dr, dc)
    return Mesh(dev, (row_axis, col_axis))
