"""GPipe-style pipeline parallelism over a mesh axis (opt-in; DESIGN.md §9).

Layers are partitioned into `n_stages` contiguous blocks whose parameters
shard over the pipeline mesh axis; microbatches stream through stages with
``lax.ppermute`` hops. The schedule is the classic GPipe ladder
(n_micro + n_stages - 1 ticks; bubble fraction (S-1)/(M+S-1)).

Scope: forward-pass building block + exactness test
(tests/test_parallel_scaffold.py::test_pipeline_matches_sequential). The production
meshes in this repo favour FSDP+TP (better roofline at 256-512 chips for
the assigned archs); PP becomes the right trade at >2 pods where the DCN
dominates — this module is the substrate for that regime.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_apply(block_fn: Callable[[Any, jax.Array], jax.Array],
                   stage_params: Any, x: jax.Array, n_micro: int,
                   mesh: Mesh, axis: str = "stage") -> jax.Array:
    """Run ``block_fn`` over `n_stages` parameter slices as a pipeline.

    stage_params: pytree, every leaf has leading dim n_stages (sharded over
    ``axis``). x: (B, ...) with B % n_micro == 0. Returns block_fn applied
    stage-by-stage, exactly equal to the sequential composition.
    """
    stages = mesh.shape[axis]
    b = x.shape[0]
    if b % n_micro:
        raise ValueError("batch must divide n_micro")
    mb = b // n_micro
    xm = x.reshape(n_micro, mb, *x.shape[1:])

    def staged(params_local, xm_local):
        idx = jax.lax.axis_index(axis)
        p = jax.tree.map(lambda a: a[0], params_local)
        ticks = n_micro + stages - 1
        perm = [(i, i + 1) for i in range(stages - 1)]

        def body(t, state):
            carry, outbuf = state
            feed = xm_local[jnp.clip(t, 0, n_micro - 1)]
            inp = jnp.where(idx == 0, feed, carry)
            out = block_fn(p, inp)
            carry_next = jax.lax.ppermute(out, axis, perm)
            widx = t - (stages - 1)
            upd = jax.lax.dynamic_update_index_in_dim(
                outbuf, out, jnp.clip(widx, 0, n_micro - 1), 0)
            write = (idx == stages - 1) & (widx >= 0)
            outbuf = jnp.where(write, upd, outbuf)
            return carry_next, outbuf

        carry0 = jnp.zeros_like(xm_local[0])
        out0 = jnp.zeros_like(xm_local)
        # mark initial carries as device-varying over the stage axis
        # (shard_map vma typing: the loop body outputs are stage-varying)
        if hasattr(jax.lax, "pvary"):
            carry0 = jax.lax.pvary(carry0, (axis,))
            out0 = jax.lax.pvary(out0, (axis,))
        _, outbuf = jax.lax.fori_loop(0, ticks, body, (carry0, out0))
        # only the last stage holds real outputs; broadcast via psum
        outbuf = jnp.where(idx == stages - 1, outbuf,
                           jnp.zeros_like(outbuf))
        return jax.lax.psum(outbuf, axis)

    out = shard_map(staged, mesh=mesh,
                    in_specs=(P(axis), P()), out_specs=P())(stage_params, xm)
    return out.reshape(b, *x.shape[1:])
