"""Ambient activation-sharding context.

Models are mesh-agnostic; the launcher (train/serve/dryrun) installs a
(mesh, rules) context and model code calls ``constrain(x, *logical_axes)``
at activation boundaries (e.g. the layer-scan carry). Logical activation
axes resolve through the same rules table as parameters:

    'act_batch' -> ('pod','data')     data parallel
    'act_seq'   -> 'model'            Megatron-style sequence parallelism
                                      (the layer carry is the saved
                                      activation; sharding it over 'model'
                                      divides checkpoint memory by TP width)

No-op when no context is installed (pure single-device execution).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

_state = threading.local()


def _current() -> Optional[Tuple[Mesh, Dict[str, Any]]]:
    return getattr(_state, "ctx", None)


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, rules: Dict[str, Any]):
    prev = _current()
    _state.ctx = (mesh, rules)
    try:
        yield
    finally:
        _state.ctx = prev


def constrain(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """Apply with_sharding_constraint(x, rules[axes]) if a context is
    installed and every sharded dim divides evenly; otherwise identity."""
    ctx = _current()
    if ctx is None or x is None:
        return x
    mesh, rules = ctx
    from .sharding import _axis_size  # local import to avoid cycle
    spec = []
    used = set()
    for dim, name in zip(x.shape, logical_axes):
        axis = rules.get(name) if name else None
        if isinstance(axis, (tuple, list)):       # drop already-used axes
            axis = tuple(a for a in axis if a not in used) or None
            if axis is not None and len(axis) == 1:
                axis = axis[0]
        elif axis in used:
            axis = None
        if axis is not None and dim % _axis_size(mesh, axis) != 0:
            axis = None
        if axis is not None:
            used.update(axis if isinstance(axis, tuple) else (axis,))
        spec.append(axis)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))
