"""Roofline-term extraction from compiled XLA artifacts (DESIGN.md §8).

Hardware model: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI (constants from the brief).

  compute    = HLO_FLOPs / (chips * PEAK_FLOPS)
  memory     = HLO_bytes / (chips * HBM_BW)
  collective = collective_operand_bytes / (chips * ICI_BW)

``cost_analysis()`` yields per-partition FLOPs/bytes for SPMD modules, so
``chips`` divides only the collective term (whose bytes we parse from the
full HLO).
"""
from __future__ import annotations

import re
from typing import Any, Dict, Optional

import numpy as np

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter",
                  "all-to-all", "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s*"
    r"(" + "|".join(COLLECTIVE_OPS) + r")[.(\s-]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output-shape bytes of every collective op, by op kind."""
    out: Dict[str, int] = {k: 0 for k in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        kind = m.group(2)
        out[kind] += _shape_bytes(m.group(1))
    return out


def roofline_terms(flops_per_chip: float, bytes_per_chip: float,
                   coll_bytes_total: float, chips: int) -> Dict[str, Any]:
    compute = flops_per_chip / PEAK_FLOPS
    memory = bytes_per_chip / HBM_BW
    collective = coll_bytes_total / (chips * ICI_BW)
    terms = {"compute_s": compute, "memory_s": memory,
             "collective_s": collective}
    dom = max(terms, key=terms.get)
    terms["dominant"] = dom.replace("_s", "")
    total = max(compute, memory, collective)
    terms["bound_s"] = total
    # fraction of the roofline the dominant term would allow if perfectly
    # overlapped with the others
    terms["flops_per_chip"] = flops_per_chip
    terms["bytes_per_chip"] = bytes_per_chip
    terms["collective_bytes"] = coll_bytes_total
    return terms


def model_flops(n_active_params: int, n_tokens: int,
                kind: str = "train") -> float:
    """MODEL_FLOPS = 6·N·D for training, 2·N·D for inference forward."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_active_params * n_tokens


def summarize(cost: Optional[Dict[str, float]], hlo_text: str, chips: int,
              n_active_params: int, n_tokens: int, kind: str
              ) -> Dict[str, Any]:
    flops = float(cost.get("flops", 0.0)) if cost else 0.0
    byts = float(cost.get("bytes accessed", 0.0)) if cost else 0.0
    coll = collective_bytes(hlo_text)
    coll_total = float(sum(coll.values()))
    terms = roofline_terms(flops, byts, coll_total, chips)
    mf = model_flops(n_active_params, n_tokens, kind)
    terms["model_flops_total"] = mf
    terms["model_flops_per_chip"] = mf / chips
    terms["useful_flops_ratio"] = (mf / chips) / flops if flops else 0.0
    terms["collective_breakdown"] = coll
    return terms
