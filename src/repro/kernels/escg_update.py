"""Pallas TPU kernel for the sublattice ESCG round (DESIGN.md §2, E3).

One program = one (th, tw) lattice tile resident in VMEM. The program plays
its K pre-generated proposals **sequentially** (``fori_loop`` with dynamic
scalar load/store) — race-free by construction — while the Pallas grid runs
all tiles in parallel across cores. This is the TPU-native replacement for
the paper's CUDA atomics: spatial disjointness instead of per-address
arbitration.

Layout notes (TPU target):
  * grid tile (th, tw): tw = 128 aligns with the lane dimension; th is a
    multiple of 8 for int32 sublane packing. Other shapes work via compiler
    padding (and in interpret mode) but 8x128 multiples are the fast path.
  * proposals arrive as (T, K) int32/float32 arrays (the paper's
    pre-generated random-number buffers, T1) and are consumed by lookup.
  * the dominance matrix (S+1, S+1) and direction table (8, 2) are tiny and
    replicated to every program.

Oracle: ``repro.core.sublattice.tile_update`` (pure jnp). The kernel must
match it bit-for-bit; see tests/test_kernels.py.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _kernel(cell_ref, dirn_ref, uact_ref, udom_ref, dom_ref, dirs_ref,
            grid_ref, out_ref, *, t_eps: float, t_eps_mu: float, k: int,
            iw: int):
    out_ref[...] = grid_ref[...]

    def body(j, _):
        # NB: row index must be a dslice, not a bare int — scalar int
        # indexing into Refs is rejected by the installed JAX (the
        # discharge rule calls .shape on every index).
        row0 = pl.ds(0, 1)
        cell = pl.load(cell_ref, (row0, pl.ds(j, 1)))[0, 0]
        dirn = pl.load(dirn_ref, (row0, pl.ds(j, 1)))[0, 0]
        ua = pl.load(uact_ref, (row0, pl.ds(j, 1)))[0, 0]
        ud = pl.load(udom_ref, (row0, pl.ds(j, 1)))[0, 0]

        r = 1 + cell // iw
        c = 1 + cell % iw
        d = pl.load(dirs_ref, (pl.ds(dirn, 1), slice(None)))[0]
        nr = r + d[0]
        nc = c + d[1]

        s = pl.load(out_ref, (pl.ds(r, 1), pl.ds(c, 1)))[0, 0]
        n = pl.load(out_ref, (pl.ds(nr, 1), pl.ds(nc, 1)))[0, 0]
        cell_dt = s.dtype
        s = s.astype(jnp.int32)
        n = n.astype(jnp.int32)

        # --- inline pure pair rule (repro.core.rules.apply_pair) ---
        same = s == n
        migrate = ua < t_eps
        interact = (ua >= t_eps) & (ua < t_eps_mu)
        reproduce = ua >= t_eps_mu
        p1 = pl.load(dom_ref, (pl.ds(s, 1), pl.ds(n, 1)))[0, 0]
        p2 = pl.load(dom_ref, (pl.ds(n, 1), pl.ds(s, 1)))[0, 0]
        kill_n = interact & (ud < p1)
        kill_s = interact & ~kill_n & (ud < p1 + p2)
        rep_to_n = reproduce & (n == 0)
        rep_to_s = reproduce & (s == 0)
        zero = jnp.int32(0)
        new_s = jnp.where(migrate, n,
                jnp.where(kill_s, zero,
                jnp.where(rep_to_s, n, s)))
        new_n = jnp.where(migrate, s,
                jnp.where(kill_n, zero,
                jnp.where(rep_to_n, s, n)))
        new_s = jnp.where(same, s, new_s)
        new_n = jnp.where(same, n, new_n)

        pl.store(out_ref, (pl.ds(r, 1), pl.ds(c, 1)),
                 new_s.astype(cell_dt).reshape(1, 1))
        pl.store(out_ref, (pl.ds(nr, 1), pl.ds(nc, 1)),
                 new_n.astype(cell_dt).reshape(1, 1))
        return 0

    lax.fori_loop(0, k, body, 0)


def escg_tile_round(grid: jax.Array, cell: jax.Array, dirn: jax.Array,
                    u_act: jax.Array, u_dom: jax.Array, dom: jax.Array,
                    dirs: jax.Array, tile_shape: Tuple[int, int],
                    t_eps: float, t_eps_mu: float,
                    interpret: bool = False) -> jax.Array:
    """Run one sublattice round over an already-shifted (H, W) grid.

    cell/dirn/u_act/u_dom: (T, K) proposal arrays in raster tile order.
    dirs: (8, 2) int32 direction table. Returns the updated grid.
    """
    h, w = grid.shape
    th, tw = tile_shape
    gh, gw = h // th, w // tw
    t, k = cell.shape
    assert t == gh * gw, (t, gh, gw)
    iw = tw - 2

    kern = functools.partial(_kernel, t_eps=float(t_eps),
                             t_eps_mu=float(t_eps_mu), k=int(k), iw=int(iw))
    prop_spec = pl.BlockSpec((1, k), lambda i, j: (i * gw + j, 0))
    full = lambda a: pl.BlockSpec(a.shape, lambda i, j: (0,) * a.ndim)

    return pl.pallas_call(
        kern,
        grid=(gh, gw),
        in_specs=[prop_spec, prop_spec, prop_spec, prop_spec,
                  full(dom), full(dirs),
                  pl.BlockSpec((th, tw), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((th, tw), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((h, w), grid.dtype),
        interpret=interpret,
    )(cell, dirn, u_act, u_dom, dom, dirs, grid)
