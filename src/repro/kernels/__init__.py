"""Pallas TPU kernels for the paper's compute hot-spots: the sublattice ESCG
update (maxStep), counter-based PRNG (T1), and density reduction. Each kernel
has a pure-jnp oracle in ref.py; ops.py holds the jitted wrappers."""
from . import density, escg_update, ops, philox, ref
