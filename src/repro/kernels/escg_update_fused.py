"""Fused-PRNG sublattice kernel (§Perf H3 iter-2, beyond-paper).

The paper pre-generates random-number buffers in device memory and tunes
their size (--numRandoms, Fig 4.2). This kernel ELIMINATES that traffic and
the tuning knob: each tile derives its proposals from Philox-4x32 counters
*inside* the kernel, in VMEM, at the moment of consumption — 16 bytes per
elementary update of HBM traffic (4 random words) drop to zero; what
remains is the grid itself.

Counter layout (``kernels.philox.philox_proposal_fields``): c0 = global
tile_id * K + j (proposal index), c1 = round index, c2 = c3 = 0; key = two
words derived from the simulation PRNG key per MCS. Uniform ints via
modulus (the paper's own technique, §3.2.1): for a 32-bit word reduced
mod m the bias is at most m / 2^32, i.e. max(interior, nbhd) / 2^32 here
— e.g. < 2^-25 for the default 8x16 tile (interior 84), and < 2^-22 only
while interior < 2^10. ``check_counter_capacity`` guards the other edge:
c0 = tile_id * K + j must not wrap uint32, or distant tiles would
silently alias each other's streams.

**Global tile identity.** ``tile_offset``/``grid_tiles_w`` let a shard of
a domain-decomposed lattice derive the SAME counters the single-device
kernel would: the program's (i, j) position is offset by the shard's
first owned tile and raster-flattened against the GLOBAL tile-grid width.
That is the whole multi-device contract — the sharded engines'
``local_kernel='fused'`` path stays bit-identical to ``pallas_fused`` for
every mesh factorization while no proposal array ever touches HBM
(DESIGN.md §6).

Oracle: host-side Philox (kernels.ref.philox4x32_ref) feeding the standard
tile oracle — bit-exact match required (tests/test_kernels.py).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .philox import philox_proposal_fields


def check_counter_capacity(n_tiles: int, k_per_tile: int) -> None:
    """Guard the c0 counter word: ``tile_id * k_per_tile + j`` is computed
    in uint32, so the GLOBAL proposal-index space must fit in 2^32 or
    distant tiles silently alias each other's Philox streams. A 3200x3200
    lattice of 8x16 tiles (80_000 tiles, k~128) uses ~10^7 counters —
    comfortably inside; the wrap point is real for k_per_tile blowups."""
    if n_tiles * k_per_tile > 2 ** 32:
        raise ValueError(
            f"fused-Philox counter overflow: {n_tiles} global tiles x "
            f"{k_per_tile} proposals/tile = {n_tiles * k_per_tile} counters "
            f"exceeds the uint32 counter space (2^32); shrink k_per_tile "
            f"or enlarge the tile")


def _apply_proposal(out_ref, dom_ref, dirs_ref, r, c, dirn, ua, ud, *,
                    t_eps: float, t_eps_mu: float):
    """One elementary update at absolute (r, c) of ``out_ref`` — the single
    source of the ESCG action semantics shared by the one-round kernel and
    the multi-MCS megakernel."""
    d = pl.load(dirs_ref, (pl.ds(dirn, 1), slice(None)))[0]
    nr = r + d[0]
    nc = c + d[1]

    s = pl.load(out_ref, (pl.ds(r, 1), pl.ds(c, 1)))[0, 0]
    n = pl.load(out_ref, (pl.ds(nr, 1), pl.ds(nc, 1)))[0, 0]
    cell_dt = s.dtype
    s = s.astype(jnp.int32)
    n = n.astype(jnp.int32)

    same = s == n
    migrate = ua < t_eps
    interact = (ua >= t_eps) & (ua < t_eps_mu)
    reproduce = ua >= t_eps_mu
    p1 = pl.load(dom_ref, (pl.ds(s, 1), pl.ds(n, 1)))[0, 0]
    p2 = pl.load(dom_ref, (pl.ds(n, 1), pl.ds(s, 1)))[0, 0]
    kill_n = interact & (ud < p1)
    kill_s = interact & ~kill_n & (ud < p1 + p2)
    rep_to_n = reproduce & (n == 0)
    rep_to_s = reproduce & (s == 0)
    zero = jnp.int32(0)
    new_s = jnp.where(migrate, n,
            jnp.where(kill_s, zero,
            jnp.where(rep_to_s, n, s)))
    new_n = jnp.where(migrate, s,
            jnp.where(kill_n, zero,
            jnp.where(rep_to_n, s, n)))
    new_s = jnp.where(same, s, new_s)
    new_n = jnp.where(same, n, new_n)

    pl.store(out_ref, (pl.ds(r, 1), pl.ds(c, 1)),
             new_s.astype(cell_dt).reshape(1, 1))
    pl.store(out_ref, (pl.ds(nr, 1), pl.ds(nc, 1)),
             new_n.astype(cell_dt).reshape(1, 1))


def _kernel(seed_ref, round_ref, off_ref, dom_ref, dirs_ref, grid_ref,
            out_ref, *, t_eps: float, t_eps_mu: float, k: int, iw: int,
            interior: int, nbhd: int, gw: int):
    i = pl.program_id(0).astype(jnp.uint32)
    j = pl.program_id(1).astype(jnp.uint32)
    # global raster tile id: program position offset by this shard's first
    # owned tile, flattened against the GLOBAL tile-grid width
    tile_id = (off_ref[0, 0] + i) * jnp.uint32(gw) + (off_ref[0, 1] + j)

    # --- derive this tile's K proposals from counters (vectorized) ---
    idx = tile_id * jnp.uint32(k) + lax.iota(jnp.uint32, k)
    cells, dirns, uact, udom = philox_proposal_fields(
        idx, round_ref[0, 0], seed_ref[0, 0], seed_ref[0, 1], interior,
        nbhd)

    out_ref[...] = grid_ref[...]

    def body(jj, _):
        cell = lax.dynamic_index_in_dim(cells, jj, keepdims=False)
        dirn = lax.dynamic_index_in_dim(dirns, jj, keepdims=False)
        ua = lax.dynamic_index_in_dim(uact, jj, keepdims=False)
        ud = lax.dynamic_index_in_dim(udom, jj, keepdims=False)
        _apply_proposal(out_ref, dom_ref, dirs_ref, 1 + cell // iw,
                        1 + cell % iw, dirn, ua, ud, t_eps=t_eps,
                        t_eps_mu=t_eps_mu)
        return 0

    lax.fori_loop(0, k, body, 0)


def escg_tile_round_fused(grid: jax.Array, seed: jax.Array,
                          round_idx: jax.Array, dom: jax.Array,
                          dirs: jax.Array, tile_shape: Tuple[int, int],
                          k_per_tile: int, t_eps: float, t_eps_mu: float,
                          neighbourhood: int = 4,
                          interpret: bool = False,
                          tile_offset: Optional[jax.Array] = None,
                          grid_tiles_w: Optional[int] = None) -> jax.Array:
    """One fused round over an already-shifted (H, W) grid.

    seed: (2,) uint32 key words; round_idx: scalar uint32.

    ``grid`` may be a SHARD of a larger lattice: ``tile_offset`` is this
    shard's (row, col) position in global tile units and ``grid_tiles_w``
    the global tile-grid width, so in-kernel counters stay keyed by global
    tile identity (defaults — zero offset, local width — recover the
    single-device kernel exactly).
    """
    h, w = grid.shape
    th, tw = tile_shape
    gh, gw = h // th, w // tw
    iw = tw - 2
    interior = (th - 2) * (tw - 2)
    if grid_tiles_w is None:
        # single-lattice call: the local tile grid IS the global one.
        # Sharded callers pass grid_tiles_w and guard with the true
        # global tile count themselves (core/sharded.py).
        check_counter_capacity(gh * gw, k_per_tile)

    kern = functools.partial(
        _kernel, t_eps=float(t_eps), t_eps_mu=float(t_eps_mu),
        k=int(k_per_tile), iw=int(iw), interior=int(interior),
        nbhd=int(neighbourhood),
        gw=int(gw if grid_tiles_w is None else grid_tiles_w))
    seed_arr = seed.reshape(1, 2).astype(jnp.uint32)
    round_arr = jnp.reshape(round_idx, (1, 1)).astype(jnp.uint32)
    if tile_offset is None:
        tile_offset = jnp.zeros((2,), jnp.uint32)
    off_arr = jnp.reshape(tile_offset, (1, 2)).astype(jnp.uint32)
    full = lambda a: pl.BlockSpec(a.shape, lambda i, j: (0,) * a.ndim)

    return pl.pallas_call(
        kern,
        grid=(gh, gw),
        in_specs=[full(seed_arr), full(round_arr), full(off_arr),
                  full(dom), full(dirs),
                  pl.BlockSpec((th, tw), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((th, tw), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((h, w), grid.dtype),
        interpret=interpret,
    )(seed_arr, round_arr, off_arr, dom, dirs, grid)


# ------------------------ multi-MCS megakernel ---------------------------- #

def _mega_kernel(seeds_ref, shifts_ref, off_ref, dom_ref, dirs_ref,
                 grid_ref, out_ref, counts_ref, *, t_eps: float,
                 t_eps_mu: float, k: int, iw: int, interior: int,
                 nbhd: int, gw: int, lgh: int, lgw: int, th: int, tw: int,
                 n_steps: int, n_counts: int):
    """K Monte-Carlo steps over the whole (resident) lattice, one launch.

    The per-tile grid of the single-round kernel is folded into an
    in-kernel loop — TPU grid iterations run sequentially on a core, so
    nothing is lost; what is gained is that the K-step shift/sweep/count
    cycle never leaves VMEM. Each fori_loop step t: torus-roll by
    -shifts[t] (concat + dynamic_slice — the frame drifts exactly like the
    jit-level ``jnp.roll`` of the one-round path), sweep every tile with
    proposals from Philox counters keyed by (seeds[t], global tile id),
    then bank per-species cell counts into counts_ref[t]."""
    h = lgh * th
    w = lgw * tw
    out_ref[...] = grid_ref[...]

    def step(t, _):
        sr = pl.load(shifts_ref, (pl.ds(t, 1), slice(None)))[0]
        g = out_ref[...]
        g = lax.dynamic_slice_in_dim(jnp.concatenate([g, g], 0),
                                     sr[0], h, 0)
        g = lax.dynamic_slice_in_dim(jnp.concatenate([g, g], 1),
                                     sr[1], w, 1)
        out_ref[...] = g
        seed = pl.load(seeds_ref, (pl.ds(t, 1), slice(None)))[0]

        def tile_body(tile_idx, _):
            ti = tile_idx // lgw
            tj = tile_idx % lgw
            tile_id = ((off_ref[0, 0] + ti.astype(jnp.uint32))
                       * jnp.uint32(gw)
                       + (off_ref[0, 1] + tj.astype(jnp.uint32)))
            idx = tile_id * jnp.uint32(k) + lax.iota(jnp.uint32, k)
            cells, dirns, uact, udom = philox_proposal_fields(
                idx, jnp.uint32(0), seed[0], seed[1], interior, nbhd)
            tr = ti * th
            tc = tj * tw

            def prop_body(jj, _):
                cell = lax.dynamic_index_in_dim(cells, jj, keepdims=False)
                dirn = lax.dynamic_index_in_dim(dirns, jj, keepdims=False)
                ua = lax.dynamic_index_in_dim(uact, jj, keepdims=False)
                ud = lax.dynamic_index_in_dim(udom, jj, keepdims=False)
                _apply_proposal(out_ref, dom_ref, dirs_ref,
                                tr + 1 + cell // iw, tc + 1 + cell % iw,
                                dirn, ua, ud, t_eps=t_eps,
                                t_eps_mu=t_eps_mu)
                return 0

            lax.fori_loop(0, k, prop_body, 0)
            return 0

        lax.fori_loop(0, lgh * lgw, tile_body, 0)

        gi = out_ref[...].astype(jnp.int32)
        for s in range(n_counts):       # static unroll over species + 1
            cnt = jnp.sum((gi == s).astype(jnp.int32))
            pl.store(counts_ref, (pl.ds(t, 1), pl.ds(s, 1)),
                     cnt.reshape(1, 1))
        return 0

    lax.fori_loop(0, n_steps, step, 0)


def escg_tile_rounds_fused(grid: jax.Array, seeds: jax.Array,
                           shifts: jax.Array, dom: jax.Array,
                           dirs: jax.Array, tile_shape: Tuple[int, int],
                           k_per_tile: int, t_eps: float, t_eps_mu: float,
                           species: int, neighbourhood: int = 4,
                           interpret: bool = False,
                           tile_offset: Optional[jax.Array] = None,
                           grid_tiles_w: Optional[int] = None):
    """K fused MCS per ``pallas_call`` (the ``k_mcs`` megakernel).

    seeds: (K, 2) uint32 per-MCS key words; shifts: (K, 2) int32 per-MCS
    torus shifts — both produced by ``engines.multi_round_inputs`` so the
    schedule is bit-identical to K driver-level calls of the one-round
    path. Returns ``(grid, counts)`` with counts (K, species + 1) int32,
    counts[t] == metrics.counts(grid after step t) — the per-MCS density
    stream the drivers need, banked in-kernel so no intermediate grid
    round-trips to HBM. The grid stays in the drifted frame, exactly like
    the roll_back=False one-round path. ``tile_offset``/``grid_tiles_w``
    key counters by global tile identity when ``grid`` is one shard."""
    h, w = grid.shape
    th, tw = tile_shape
    lgh, lgw = h // th, w // tw
    iw = tw - 2
    interior = (th - 2) * (tw - 2)
    n_steps = int(seeds.shape[0])
    if grid_tiles_w is None:
        check_counter_capacity(lgh * lgw, k_per_tile)

    kern = functools.partial(
        _mega_kernel, t_eps=float(t_eps), t_eps_mu=float(t_eps_mu),
        k=int(k_per_tile), iw=int(iw), interior=int(interior),
        nbhd=int(neighbourhood),
        gw=int(lgw if grid_tiles_w is None else grid_tiles_w),
        lgh=int(lgh), lgw=int(lgw), th=int(th), tw=int(tw),
        n_steps=n_steps, n_counts=int(species) + 1)
    seeds_arr = seeds.reshape(n_steps, 2).astype(jnp.uint32)
    shifts_arr = shifts.reshape(n_steps, 2).astype(jnp.int32)
    if tile_offset is None:
        tile_offset = jnp.zeros((2,), jnp.uint32)
    off_arr = jnp.reshape(tile_offset, (1, 2)).astype(jnp.uint32)

    # single program, whole lattice resident: no grid, full-array refs
    return pl.pallas_call(
        kern,
        out_shape=(jax.ShapeDtypeStruct((h, w), grid.dtype),
                   jax.ShapeDtypeStruct((n_steps, int(species) + 1),
                                        jnp.int32)),
        interpret=interpret,
    )(seeds_arr, shifts_arr, off_arr, dom, dirs, grid)
