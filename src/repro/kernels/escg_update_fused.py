"""Fused-PRNG sublattice kernel (§Perf H3 iter-2, beyond-paper).

The paper pre-generates random-number buffers in device memory and tunes
their size (--numRandoms, Fig 4.2). This kernel ELIMINATES that traffic and
the tuning knob: each tile derives its proposals from Philox-4x32 counters
*inside* the kernel, in VMEM, at the moment of consumption — 16 bytes per
elementary update of HBM traffic (4 random words) drop to zero; what
remains is the grid itself.

Counter layout (``kernels.philox.philox_proposal_fields``): c0 = global
tile_id * K + j (proposal index), c1 = round index, c2 = c3 = 0; key = two
words derived from the simulation PRNG key per MCS. Uniform ints via
modulus (the paper's own technique, §3.2.1 — the bias at 32 bits is
< 2^-22 for any lattice tile).

**Global tile identity.** ``tile_offset``/``grid_tiles_w`` let a shard of
a domain-decomposed lattice derive the SAME counters the single-device
kernel would: the program's (i, j) position is offset by the shard's
first owned tile and raster-flattened against the GLOBAL tile-grid width.
That is the whole multi-device contract — the sharded engines'
``local_kernel='fused'`` path stays bit-identical to ``pallas_fused`` for
every mesh factorization while no proposal array ever touches HBM
(DESIGN.md §6).

Oracle: host-side Philox (kernels.ref.philox4x32_ref) feeding the standard
tile oracle — bit-exact match required (tests/test_kernels.py).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .philox import philox_proposal_fields


def _kernel(seed_ref, round_ref, off_ref, dom_ref, dirs_ref, grid_ref,
            out_ref, *, t_eps: float, t_eps_mu: float, k: int, iw: int,
            interior: int, nbhd: int, gw: int):
    i = pl.program_id(0).astype(jnp.uint32)
    j = pl.program_id(1).astype(jnp.uint32)
    # global raster tile id: program position offset by this shard's first
    # owned tile, flattened against the GLOBAL tile-grid width
    tile_id = (off_ref[0, 0] + i) * jnp.uint32(gw) + (off_ref[0, 1] + j)

    # --- derive this tile's K proposals from counters (vectorized) ---
    idx = tile_id * jnp.uint32(k) + lax.iota(jnp.uint32, k)
    cells, dirns, uact, udom = philox_proposal_fields(
        idx, round_ref[0, 0], seed_ref[0, 0], seed_ref[0, 1], interior,
        nbhd)

    out_ref[...] = grid_ref[...]

    def body(jj, _):
        cell = lax.dynamic_index_in_dim(cells, jj, keepdims=False)
        dirn = lax.dynamic_index_in_dim(dirns, jj, keepdims=False)
        ua = lax.dynamic_index_in_dim(uact, jj, keepdims=False)
        ud = lax.dynamic_index_in_dim(udom, jj, keepdims=False)

        r = 1 + cell // iw
        c = 1 + cell % iw
        d = pl.load(dirs_ref, (pl.ds(dirn, 1), slice(None)))[0]
        nr = r + d[0]
        nc = c + d[1]

        s = pl.load(out_ref, (pl.ds(r, 1), pl.ds(c, 1)))[0, 0]
        n = pl.load(out_ref, (pl.ds(nr, 1), pl.ds(nc, 1)))[0, 0]
        cell_dt = s.dtype
        s = s.astype(jnp.int32)
        n = n.astype(jnp.int32)

        same = s == n
        migrate = ua < t_eps
        interact = (ua >= t_eps) & (ua < t_eps_mu)
        reproduce = ua >= t_eps_mu
        p1 = pl.load(dom_ref, (pl.ds(s, 1), pl.ds(n, 1)))[0, 0]
        p2 = pl.load(dom_ref, (pl.ds(n, 1), pl.ds(s, 1)))[0, 0]
        kill_n = interact & (ud < p1)
        kill_s = interact & ~kill_n & (ud < p1 + p2)
        rep_to_n = reproduce & (n == 0)
        rep_to_s = reproduce & (s == 0)
        zero = jnp.int32(0)
        new_s = jnp.where(migrate, n,
                jnp.where(kill_s, zero,
                jnp.where(rep_to_s, n, s)))
        new_n = jnp.where(migrate, s,
                jnp.where(kill_n, zero,
                jnp.where(rep_to_n, s, n)))
        new_s = jnp.where(same, s, new_s)
        new_n = jnp.where(same, n, new_n)

        pl.store(out_ref, (pl.ds(r, 1), pl.ds(c, 1)),
                 new_s.astype(cell_dt).reshape(1, 1))
        pl.store(out_ref, (pl.ds(nr, 1), pl.ds(nc, 1)),
                 new_n.astype(cell_dt).reshape(1, 1))
        return 0

    lax.fori_loop(0, k, body, 0)


def escg_tile_round_fused(grid: jax.Array, seed: jax.Array,
                          round_idx: jax.Array, dom: jax.Array,
                          dirs: jax.Array, tile_shape: Tuple[int, int],
                          k_per_tile: int, t_eps: float, t_eps_mu: float,
                          neighbourhood: int = 4,
                          interpret: bool = False,
                          tile_offset: Optional[jax.Array] = None,
                          grid_tiles_w: Optional[int] = None) -> jax.Array:
    """One fused round over an already-shifted (H, W) grid.

    seed: (2,) uint32 key words; round_idx: scalar uint32.

    ``grid`` may be a SHARD of a larger lattice: ``tile_offset`` is this
    shard's (row, col) position in global tile units and ``grid_tiles_w``
    the global tile-grid width, so in-kernel counters stay keyed by global
    tile identity (defaults — zero offset, local width — recover the
    single-device kernel exactly).
    """
    h, w = grid.shape
    th, tw = tile_shape
    gh, gw = h // th, w // tw
    iw = tw - 2
    interior = (th - 2) * (tw - 2)

    kern = functools.partial(
        _kernel, t_eps=float(t_eps), t_eps_mu=float(t_eps_mu),
        k=int(k_per_tile), iw=int(iw), interior=int(interior),
        nbhd=int(neighbourhood),
        gw=int(gw if grid_tiles_w is None else grid_tiles_w))
    seed_arr = seed.reshape(1, 2).astype(jnp.uint32)
    round_arr = jnp.reshape(round_idx, (1, 1)).astype(jnp.uint32)
    if tile_offset is None:
        tile_offset = jnp.zeros((2,), jnp.uint32)
    off_arr = jnp.reshape(tile_offset, (1, 2)).astype(jnp.uint32)
    full = lambda a: pl.BlockSpec(a.shape, lambda i, j: (0,) * a.ndim)

    return pl.pallas_call(
        kern,
        grid=(gh, gw),
        in_specs=[full(seed_arr), full(round_arr), full(off_arr),
                  full(dom), full(dirs),
                  pl.BlockSpec((th, tw), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((th, tw), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((h, w), grid.dtype),
        interpret=interpret,
    )(seed_arr, round_arr, off_arr, dom, dirs, grid)
