"""Pure-jnp / numpy oracles for every Pallas kernel in this package."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.rng import ProposalBatch
from ..core.sublattice import from_tiles, tile_update, to_tiles

PHILOX_M0 = np.uint64(0xD2511F53)
PHILOX_M1 = np.uint64(0xCD9E8D57)
PHILOX_W0 = np.uint32(0x9E3779B9)
PHILOX_W1 = np.uint32(0xBB67AE85)


def escg_tile_round_ref(grid: jax.Array, cell, dirn, u_act, u_dom, dom,
                        tile_shape: Tuple[int, int], t_eps: float,
                        t_eps_mu: float) -> jax.Array:
    """Oracle for kernels.escg_update: vmapped sequential tile updates."""
    h, w = grid.shape
    th, tw = tile_shape
    tiles = to_tiles(grid, th, tw)
    upd = jax.vmap(lambda t, c, d, ua, ud: tile_update(
        t, ProposalBatch(c, d, ua, ud), t_eps, t_eps_mu, jnp.asarray(dom)))
    tiles = upd(tiles, cell, dirn, u_act, u_dom)
    return from_tiles(tiles, h, w)


def philox4x32_ref(c0, c1, c2, c3, k0: int, k1: int):
    """numpy uint64-based Philox-4x32-10 (independent of the kernel's
    16-bit-limb multiplies)."""
    c0 = np.asarray(c0, np.uint32)
    c1 = np.asarray(c1, np.uint32)
    c2 = np.asarray(c2, np.uint32)
    c3 = np.asarray(c3, np.uint32)
    k0 = np.uint32(k0)
    k1 = np.uint32(k1)
    for r in range(10):
        if r > 0:
            with np.errstate(over="ignore"):   # uint32 wrap is the algorithm
                k0 = np.uint32(k0 + PHILOX_W0)
                k1 = np.uint32(k1 + PHILOX_W1)
        p0 = c0.astype(np.uint64) * PHILOX_M0
        p1 = c2.astype(np.uint64) * PHILOX_M1
        hi0 = (p0 >> np.uint64(32)).astype(np.uint32)
        lo0 = p0.astype(np.uint32)
        hi1 = (p1 >> np.uint64(32)).astype(np.uint32)
        lo1 = p1.astype(np.uint32)
        c0, c1, c2, c3 = (hi1 ^ c1 ^ k0, lo1, hi0 ^ c3 ^ k1, lo0)
    return c0, c1, c2, c3


def philox_bits_ref(n: int, seed: Tuple[int, int], stream: int = 0,
                    block: int = 1024) -> np.ndarray:
    """Matches kernels.philox.philox_bits layout exactly."""
    n_ctr = -(-n // 4)
    n_blocks = -(-n_ctr // block)
    total = n_blocks * block
    idx = np.arange(total, dtype=np.uint32)
    x0, x1, x2, x3 = philox4x32_ref(
        idx, np.full(total, stream, np.uint32),
        np.zeros(total, np.uint32), np.zeros(total, np.uint32),
        seed[0], seed[1])
    return np.stack([x0, x1, x2, x3], axis=0).T.reshape(-1)[:n]


def density_ref(grid: jax.Array, species: int) -> jax.Array:
    return jnp.bincount(grid.reshape(-1), length=species + 1)


def fused_proposals_ref(n_tiles: int, k: int, interior: int, nbhd: int,
                        seed, round_idx: int):
    """Host-side derivation of the fused kernel's proposal stream (same
    Philox counters/mapping) -> (cell, dirn, u_act, u_dom), each
    (n_tiles, k)."""
    idx = np.arange(n_tiles * k, dtype=np.uint32)
    c1 = np.full(idx.shape, round_idx, np.uint32)
    z = np.zeros(idx.shape, np.uint32)
    x0, x1, x2, x3 = philox4x32_ref(idx, c1, z, z, int(seed[0]),
                                    int(seed[1]))
    cell = (x0 % np.uint32(interior)).astype(np.int32).reshape(n_tiles, k)
    dirn = (x1 % np.uint32(nbhd)).astype(np.int32).reshape(n_tiles, k)
    ua = ((x2 >> 8).astype(np.float32) * 2.0 ** -24).reshape(n_tiles, k)
    ud = ((x3 >> 8).astype(np.float32) * 2.0 ** -24).reshape(n_tiles, k)
    return cell, dirn, ua, ud
