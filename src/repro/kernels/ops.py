"""Jitted public wrappers around the Pallas kernels.

``interpret`` defaults to True off-TPU (this container is CPU-only; the
kernels target TPU and are validated via the interpreter per the brief).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.lattice import DIRS
from ..core.rng import ProposalBatch
from . import density as density_kernel
from . import escg_update as escg_kernel
from . import escg_update_fused as escg_fused_kernel
from . import philox as philox_kernel


def _default_interpret(interpret: Optional[bool]) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("tile_shape", "t_eps",
                                             "t_eps_mu", "interpret",
                                             "roll_back"))
def _escg_round_impl(grid, cell, dirn, u_act, u_dom, shift, dom,
                     tile_shape, t_eps, t_eps_mu, interpret, roll_back):
    dirs = jnp.asarray(DIRS, jnp.int32)
    g = jnp.roll(grid, (-shift[0], -shift[1]), (0, 1))
    g = escg_kernel.escg_tile_round(
        g, cell, dirn, u_act, u_dom, jnp.asarray(dom, jnp.float32), dirs,
        tile_shape, t_eps, t_eps_mu, interpret=interpret)
    if roll_back:
        g = jnp.roll(g, (shift[0], shift[1]), (0, 1))
    return g


def escg_round(grid: jax.Array, props: ProposalBatch, shift: jax.Array,
               dom: jax.Array, tile_shape: Tuple[int, int], t_eps: float,
               t_eps_mu: float, interpret: Optional[bool] = None,
               roll_back: bool = True) -> jax.Array:
    """Drop-in Pallas replacement for core.sublattice.run_round."""
    return _escg_round_impl(grid, props.cell, props.dirn, props.u_act,
                            props.u_dom, shift, dom, tile_shape,
                            float(t_eps), float(t_eps_mu),
                            _default_interpret(interpret), roll_back)


def philox_bits(n: int, seed: Tuple[int, int] = (0, 0), stream: int = 0,
                block: int = 1024,
                interpret: Optional[bool] = None) -> jax.Array:
    return philox_kernel.philox_bits(n, seed, stream, block,
                                     _default_interpret(interpret))


def philox_uniform(n: int, seed: Tuple[int, int] = (0, 0), stream: int = 0,
                   block: int = 1024,
                   interpret: Optional[bool] = None) -> jax.Array:
    return philox_kernel.philox_uniform(n, seed, stream, block,
                                        _default_interpret(interpret))


def density_counts(grid: jax.Array, species: int,
                   interpret: Optional[bool] = None) -> jax.Array:
    return density_kernel.density_counts(
        grid, species, interpret=_default_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("tile_shape", "k_per_tile",
                                             "t_eps", "t_eps_mu",
                                             "neighbourhood", "interpret",
                                             "roll_back", "grid_tiles_w"))
def _escg_round_fused_impl(grid, seed, round_idx, shift, tile_offset, dom,
                           tile_shape, k_per_tile, t_eps, t_eps_mu,
                           neighbourhood, interpret, roll_back,
                           grid_tiles_w):
    dirs = jnp.asarray(DIRS, jnp.int32)
    g = jnp.roll(grid, (-shift[0], -shift[1]), (0, 1))
    g = escg_fused_kernel.escg_tile_round_fused(
        g, seed, round_idx, jnp.asarray(dom, jnp.float32), dirs,
        tile_shape, k_per_tile, t_eps, t_eps_mu, neighbourhood,
        interpret=interpret, tile_offset=tile_offset,
        grid_tiles_w=grid_tiles_w)
    if roll_back:
        g = jnp.roll(g, (shift[0], shift[1]), (0, 1))
    return g


def escg_round_fused(grid, seed, round_idx, shift, dom, tile_shape,
                     k_per_tile, t_eps, t_eps_mu, neighbourhood=4,
                     interpret=None, roll_back=True, tile_offset=None,
                     grid_tiles_w=None):
    """Fused-PRNG sublattice round: proposals derived in-kernel from Philox
    counters (zero proposal HBM traffic; see escg_update_fused).
    ``tile_offset``/``grid_tiles_w`` key the counters by GLOBAL tile
    identity when ``grid`` is one shard of a larger lattice."""
    if tile_offset is None:
        tile_offset = jnp.zeros((2,), jnp.uint32)
    return _escg_round_fused_impl(grid, seed, round_idx, shift, tile_offset,
                                  dom, tile_shape, k_per_tile, float(t_eps),
                                  float(t_eps_mu), neighbourhood,
                                  _default_interpret(interpret), roll_back,
                                  grid_tiles_w)


@functools.partial(jax.jit, static_argnames=("tile_shape", "k_per_tile",
                                             "t_eps", "t_eps_mu", "species",
                                             "neighbourhood", "interpret",
                                             "grid_tiles_w"))
def _escg_rounds_fused_impl(grid, seeds, shifts, tile_offset, dom,
                            tile_shape, k_per_tile, t_eps, t_eps_mu,
                            species, neighbourhood, interpret,
                            grid_tiles_w):
    dirs = jnp.asarray(DIRS, jnp.int32)
    return escg_fused_kernel.escg_tile_rounds_fused(
        grid, seeds, shifts, jnp.asarray(dom, jnp.float32), dirs,
        tile_shape, k_per_tile, t_eps, t_eps_mu, species, neighbourhood,
        interpret=interpret, tile_offset=tile_offset,
        grid_tiles_w=grid_tiles_w)


def escg_rounds_fused(grid, seeds, shifts, dom, tile_shape, k_per_tile,
                      t_eps, t_eps_mu, species, neighbourhood=4,
                      interpret=None, tile_offset=None, grid_tiles_w=None):
    """K fused MCS in ONE pallas_call (the ``k_mcs`` megakernel): the
    per-step torus roll happens IN-KERNEL, so unlike ``escg_round_fused``
    there is no jit-level roll and no roll_back knob — the grid comes back
    in the drifted frame of the last step, with per-step species counts
    (K, species + 1) banked alongside (see escg_update_fused)."""
    if tile_offset is None:
        tile_offset = jnp.zeros((2,), jnp.uint32)
    return _escg_rounds_fused_impl(grid, seeds, shifts, tile_offset, dom,
                                   tile_shape, k_per_tile, float(t_eps),
                                   float(t_eps_mu), int(species),
                                   neighbourhood,
                                   _default_interpret(interpret),
                                   grid_tiles_w)
