"""Pallas density/histogram kernel (paper §3.2.2, densities.metal).

The paper offloads per-MCS density counting to the GPU with an atomic
species-count array. TPU adaptation: a sequential-grid reduction — each
program one-hot-counts its VMEM block and accumulates into a single output
block (Pallas TPU grids execute in order, so the ``program_id == 0`` init +
accumulate pattern replaces atomics).

Oracle: ``jnp.bincount`` (repro.kernels.ref.density_ref).

:func:`density_counts_sharded` lifts the kernel into a ``shard_map``
region: each device one-hot-counts its local block and the partials are
``psum``med into global counts — the observable pipeline's count path on
domain-decomposed lattices (DESIGN.md §11).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P


def _kernel(grid_ref, out_ref, *, n_labels: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    block = grid_ref[...]
    labels = jax.lax.iota(jnp.int32, n_labels).reshape(1, 1, n_labels)
    onehot = (block[:, :, None] == labels).astype(jnp.int32)
    out_ref[0, :] += jnp.sum(onehot, axis=(0, 1))


def density_counts(grid: jax.Array, species: int, block_rows: int = 8,
                   interpret: bool = False) -> jax.Array:
    """Counts per label 0..S over an (H, W) int32 grid."""
    h, w = grid.shape
    if h % block_rows:
        block_rows = 1
    n_labels = species + 1
    kern = functools.partial(_kernel, n_labels=n_labels)
    out = pl.pallas_call(
        kern,
        grid=(h // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, w), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, n_labels), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, n_labels), jnp.int32),
        interpret=interpret,
    )(grid)
    return out[0]


def density_counts_sharded(grid: jax.Array, species: int, mesh: Mesh,
                           row_axis: str = "rows", col_axis: str = "cols",
                           block_rows: int = 8,
                           interpret: bool = False) -> jax.Array:
    """Global label counts of a lattice sharded P(row_axis, col_axis).

    Runs :func:`density_counts` per shard inside a ``shard_map`` region
    and all-reduces the per-device partial histograms with ``lax.psum`` —
    no device ever materializes a remote block. Bit-identical to
    ``density_counts`` (and to the ``density_ref`` bincount oracle) on
    the gathered lattice: one-hot integer sums are order-independent.
    """
    def local_counts(gl):
        part = density_counts(gl, species, block_rows=block_rows,
                              interpret=interpret)
        return jax.lax.psum(part, (row_axis, col_axis))

    return shard_map(local_counts, mesh=mesh,
                     in_specs=P(row_axis, col_axis), out_specs=P(),
                     check_rep=False)(grid)
