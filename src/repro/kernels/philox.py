"""Pallas Philox-4x32-10 counter-based PRNG kernel (paper T1, Fig 4.1).

The paper fought Mersenne-Twister pathologies on GPU (624-word per-thread
state, seed hashing, burn-in, striping artefacts — Fig 3.4) and suggests
counter-based generators (PCG) as future work. On TPU the answer is a
counter-based PRNG: stateless, perfectly parallel, no burn-in by
construction. Philox-4x32-10 (Salmon et al., Random123) is implemented with
16-bit-decomposed 32x32->64 multiplies so it lowers on hardware without
64-bit integer support.

Oracle: ``repro.kernels.ref.philox4x32_ref`` (numpy uint64) + published
Random123 known-answer vectors.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

PHILOX_M0 = 0xD2511F53
PHILOX_M1 = 0xCD9E8D57
PHILOX_W0 = 0x9E3779B9
PHILOX_W1 = 0xBB67AE85
ROUNDS = 10


def _mulhilo(a: jax.Array, b: int) -> Tuple[jax.Array, jax.Array]:
    """(hi, lo) of the 32x32->64 product, via 16-bit limbs (TPU-safe)."""
    a = a.astype(jnp.uint32)
    bl = jnp.uint32(b & 0xFFFF)
    bh = jnp.uint32((b >> 16) & 0xFFFF)
    al = a & 0xFFFF
    ah = a >> 16
    lo = (a * jnp.uint32(b)).astype(jnp.uint32)          # wraps mod 2^32
    albl = al * bl
    mid1 = ah * bl + (albl >> 16)                        # < 2^32, no wrap
    mid2 = al * bh
    mid = mid1 + mid2                                    # may wrap
    carry = (mid < mid1).astype(jnp.uint32)
    hi = ah * bh + (mid >> 16) + (carry << 16)
    return hi, lo


def philox_rounds(c0, c1, c2, c3, k0, k1):
    """10 Philox rounds on uint32 arrays; returns 4 output words."""
    for r in range(ROUNDS):
        if r > 0:
            k0 = k0 + jnp.uint32(PHILOX_W0)
            k1 = k1 + jnp.uint32(PHILOX_W1)
        hi0, lo0 = _mulhilo(c0, PHILOX_M0)
        hi1, lo1 = _mulhilo(c2, PHILOX_M1)
        c0, c1, c2, c3 = (hi1 ^ c1 ^ k0, lo1, hi0 ^ c3 ^ k1, lo0)
    return c0, c1, c2, c3


def philox_proposal_fields(idx, round_idx, k0, k1, interior: int,
                           nbhd: int):
    """Map Philox counters to one ESCG proposal each (the fused-kernel
    counter layout, DESIGN.md §3): counter = (idx, round_idx, 0, 0) with
    ``idx`` the GLOBAL proposal index (global tile id * K + j), key =
    ``(k0, k1)``. The four output words become (cell, dirn, u_act, u_dom);
    uniform ints via modulus (paper §3.2.1 — bias at most
    max(interior, nbhd) / 2^32 for a 32-bit word reduced mod m), uniform
    floats from the top 24 bits (exact in f32, half-open [0, 1)).

    Keying by global identity only — never by shard layout — is what lets
    every device of the sharded engines regenerate exactly the streams of
    the (tile, proposal) pairs it owns, bit-identical to the single-device
    ``pallas_fused`` engine. Host oracle: ``ref.fused_proposals_ref``.
    """
    idx = idx.astype(jnp.uint32)
    c1 = jnp.full(idx.shape, round_idx, jnp.uint32)
    zeros = jnp.zeros(idx.shape, jnp.uint32)
    x0, x1, x2, x3 = philox_rounds(idx, c1, zeros, zeros, k0, k1)
    cell = (x0 % jnp.uint32(interior)).astype(jnp.int32)
    dirn = (x1 % jnp.uint32(nbhd)).astype(jnp.int32)
    u_act = (x2 >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(2 ** -24)
    u_dom = (x3 >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(2 ** -24)
    return cell, dirn, u_act, u_dom


def _kernel(seed_ref, out_ref, *, block: int, base_stream: int):
    i = pl.program_id(0)
    k0 = seed_ref[0, 0]
    k1 = seed_ref[0, 1]
    idx = (i * block + jax.lax.iota(jnp.uint32, block))
    c0 = idx
    c1 = jnp.full((block,), base_stream, jnp.uint32)
    c2 = jnp.zeros((block,), jnp.uint32)
    c3 = jnp.zeros((block,), jnp.uint32)
    x0, x1, x2, x3 = philox_rounds(c0, c1, c2, c3, k0, k1)
    out_ref[0, :] = x0
    out_ref[1, :] = x1
    out_ref[2, :] = x2
    out_ref[3, :] = x3


def philox_bits(n: int, seed: Tuple[int, int], stream: int = 0,
                block: int = 1024, interpret: bool = False) -> jax.Array:
    """Generate ``n`` uint32 words (4 words per counter, n rounded up to
    4*block internally, truncated on return)."""
    n_ctr = -(-n // 4)
    n_blocks = -(-n_ctr // block)
    seed_arr = jnp.array([[seed[0], seed[1]]], dtype=jnp.uint32)
    kern = functools.partial(_kernel, block=block, base_stream=stream)
    out = pl.pallas_call(
        kern,
        grid=(n_blocks,),
        in_specs=[pl.BlockSpec((1, 2), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((4, block), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((4, n_blocks * block), jnp.uint32),
        interpret=interpret,
    )(seed_arr)
    return out.T.reshape(-1)[:n]


def philox_uniform(n: int, seed: Tuple[int, int], stream: int = 0,
                   block: int = 1024, interpret: bool = False) -> jax.Array:
    """n float32 uniforms in [0, 1): top 24 bits * 2^-24 (exact in f32,
    guarantees the half-open interval — bits * 2^-32 can round to 1.0)."""
    bits = philox_bits(n, seed, stream, block, interpret)
    return (bits >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(2.0 ** -24)
