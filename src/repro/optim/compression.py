"""int8 error-feedback gradient compression (DESIGN.md §9).

Motivation: on multi-pod meshes the gradient reduce-scatter/all-reduce over
the DCN dominates the collective roofline term. Quantizing grads to int8
with per-(leading-slice) scales cuts bytes-on-wire 2x (vs bf16) / 4x (vs
f32); the quantization residual is fed back into the next step's grads
(error feedback), which keeps SGD-style convergence guarantees.

Usage: ``compressor = EFCompressor(); train_step = make_train_step(model,
compressor=compressor.wrap)`` — the EF buffer rides in the optimizer state
extension returned by ``state_specs``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from ..models import spec as spec_mod
from ..models.spec import ParamSpec


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-row (leading-axis) int8 quantization."""
    xf = x.astype(jnp.float32)
    red = tuple(range(1, xf.ndim)) or (0,)
    scale = jnp.max(jnp.abs(xf), axis=red, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array,
                    dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def ef_state_specs(param_specs) -> Any:
    """Error-feedback residual buffer per param (same shape, bf16)."""
    return spec_mod.map_specs(
        lambda p, s: dataclasses.replace(s, init="zeros", dtype="bfloat16"),
        param_specs)


def compress_grads(grads: Any, ef: Any) -> Tuple[Any, Any]:
    """Apply EF + int8 round-trip to every grad leaf. Returns
    (compressed-dequantized grads, new EF residuals).

    The round-trip models exactly what arrives after an int8 collective:
    values identical to a quantize -> all-reduce(int8->f32 accum) ->
    dequantize pipeline on real interconnect.
    """
    def one(g, e):
        gf = g.astype(jnp.float32) + e.astype(jnp.float32)
        q, scale = quantize_int8(gf)
        deq = dequantize_int8(q, scale)
        resid = (gf - deq).astype(jnp.bfloat16)
        return deq.astype(g.dtype), resid

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_e = jax.tree.unflatten(tdef, [o[1] for o in out])
    return new_g, new_e


def wire_bytes(param_specs, dtype_bytes: int = 4) -> Tuple[int, int]:
    """(uncompressed, compressed) gradient bytes per sync for reporting."""
    n = spec_mod.count_params(param_specs)
    comp = n  # int8 payload
    # + one f32 scale per leading row — negligible, ignore for the headline
    return n * dtype_bytes, comp
