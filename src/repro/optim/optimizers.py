"""Optimizers built on the ParamSpec tree system (no external deps).

AdamW for everything up to a few hundred B params; Adafactor (factored second
moments, no first moment) for the 1T-class MoE where AdamW's fp32 moments
exceed the per-chip HBM budget (see configs/kimi_k2_1t_a32b.py).
Optimizer-state *specs* mirror parameter specs so the sharding rules apply to
optimizer state unchanged (ZeRO-style: state shards wherever params shard).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from ..models import spec as spec_mod
from ..models.spec import ParamSpec


class Optimizer(NamedTuple):
    name: str
    state_specs: Callable[[Any], Any]          # param_specs -> state specs
    apply: Callable[..., Tuple[Any, Any]]      # (params,grads,state,lr,step)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    # scale in the grad's own dtype: an f32 round-trip materializes an fp32
    # copy of every grad leaf (tens of GB for the 1T MoE)
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def _map_leaves(fn, params, grads, state):
    """Recurse param/grad/state dicts in lockstep; state subtree per leaf.
    Returns (new_params, new_state)."""
    if isinstance(params, dict):
        out = {k: _map_leaves(fn, params[k], grads[k], state[k])
               for k in params}
        return ({k: v[0] for k, v in out.items()},
                {k: v[1] for k, v in out.items()})
    return _chunked(fn, params, grads, state)


def _chunked(fn, p, g, st):
    """Apply an elementwise update per slice of the leading (layer-stack)
    axis via lax.map. Without this, fp32 temporaries materialize for whole
    stacked leaves — for the 1T MoE that is tens of GB per leaf (the update
    math touches only the trailing axes, so slicing axis 0 is exact)."""
    if hasattr(p, "ndim") and p.ndim >= 3 and p.shape[0] > 1:
        return jax.lax.map(lambda args: fn(*args), (p, g, st))
    return fn(p, g, st)


# --------------------------------- AdamW ---------------------------------- #

def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1, moment_dtype: str = "float32"
          ) -> Optimizer:
    def state_specs(param_specs):
        def f(path, s: ParamSpec):
            z = dataclasses.replace(s, init="zeros", dtype=moment_dtype)
            return {"m": z, "v": z}
        return spec_mod.map_specs(f, param_specs)

    def apply(params, grads, state, lr, step):
        t = (step + 1).astype(jnp.float32)
        c1 = 1.0 - jnp.power(b1, t)
        c2 = 1.0 - jnp.power(b2, t)
        md = jnp.dtype(moment_dtype)

        def upd(p, g, st):
            gf = g.astype(jnp.float32)
            m = b1 * st["m"].astype(jnp.float32) + (1 - b1) * gf
            v = b2 * st["v"].astype(jnp.float32) + (1 - b2) * gf * gf
            u = (m / c1) / (jnp.sqrt(v / c2) + eps)
            pf = p.astype(jnp.float32)
            pf = pf - lr * (u + weight_decay * pf)
            return pf.astype(p.dtype), {"m": m.astype(md), "v": v.astype(md)}

        return _map_leaves(upd, params, grads, state)

    return Optimizer("adamw", state_specs, apply)


# ------------------------------- Adafactor -------------------------------- #

def adafactor(eps: float = 1e-30, clip_threshold: float = 1.0,
              decay: float = 0.8, weight_decay: float = 0.0) -> Optimizer:
    """Factored second moments for >=2-D params; scalars/vectors keep a full
    second moment. No first moment."""

    def state_specs(param_specs):
        def f(path, s: ParamSpec):
            if len(s.shape) >= 2:
                return {
                    "vr": ParamSpec(s.shape[:-1], s.axes[:-1], init="zeros",
                                    dtype="float32"),
                    "vc": ParamSpec(s.shape[:-2] + s.shape[-1:],
                                    s.axes[:-2] + s.axes[-1:], init="zeros",
                                    dtype="float32"),
                }
            return {"v": ParamSpec(s.shape, s.axes, init="zeros",
                                   dtype="float32")}
        return spec_mod.map_specs(f, param_specs)

    def apply(params, grads, state, lr, step):
        t = (step + 1).astype(jnp.float32)
        beta = 1.0 - jnp.power(t, -decay)

        def upd(p, g, st):
            gf = g.astype(jnp.float32)
            g2 = gf * gf + eps
            if "v" in st:
                v = beta * st["v"] + (1 - beta) * g2
                u = gf * jax.lax.rsqrt(v + eps)
                new_st = {"v": v}
            else:
                vr = beta * st["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * st["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True),
                                    eps)
                vhat = (vr / denom)[..., None] * vc[..., None, :]
                u = gf * jax.lax.rsqrt(vhat + eps)
                new_st = {"vr": vr, "vc": vc}
            rms_u = jnp.sqrt(jnp.mean(u * u) + eps)
            u = u / jnp.maximum(1.0, rms_u / clip_threshold)
            pf = p.astype(jnp.float32)
            pf = pf - lr * (u + weight_decay * pf)
            return pf.astype(p.dtype), new_st

        return _map_leaves(upd, params, grads, state)

    return Optimizer("adafactor", state_specs, apply)


def get_optimizer(name: str) -> Optimizer:
    if name == "adamw":
        return adamw()
    if name == "adafactor":
        return adafactor()
    raise ValueError(f"unknown optimizer {name}")


# ------------------------------- schedules -------------------------------- #

def cosine_schedule(peak_lr: float = 3e-4, warmup: int = 100,
                    total: int = 10_000, floor: float = 0.1):
    def lr(step):
        s = step.astype(jnp.float32)
        warm = s / jnp.maximum(1.0, warmup)
        prog = jnp.clip((s - warmup) / jnp.maximum(1.0, total - warmup),
                        0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return peak_lr * jnp.where(s < warmup, warm, cos)
    return lr
