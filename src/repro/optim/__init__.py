from .optimizers import (Optimizer, adafactor, adamw, clip_by_global_norm,
                         cosine_schedule, get_optimizer, global_norm)
