from .synthetic import SyntheticTokens, batch_for_model
