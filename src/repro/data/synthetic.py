"""Deterministic synthetic data pipeline.

Serves the LM training examples/benchmarks: an infinite, seeded,
shard-aware token stream with next-token labels. Each (host, step) pair
derives its batch from a counter-based key, so restarts reproduce the same
stream with no data service (the same counter-PRNG philosophy as the ESCG
random streams, T1).
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


class SyntheticTokens:
    """Markov-ish token stream: mixture of n-gram structure + noise so the
    CE loss has learnable signal (pure uniform tokens would be flat)."""

    def __init__(self, vocab: int, seq_len: int, batch: int, seed: int = 0,
                 structure: float = 0.8):
        self.vocab = vocab
        self.seq_len = seq_len
        self.batch = batch
        self.seed = seed
        self.structure = structure

    PERIOD = 16

    def batch_at(self, step: int) -> Dict[str, jax.Array]:
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        k1, k2, k3, k4 = jax.random.split(key, 4)
        b, s, v = self.batch, self.seq_len, self.vocab
        # structured component: periodic sequences (token_t = token_{t-P})
        # — learnable by induction heads within a few hundred steps, unlike
        # modular-arithmetic maps which need grokking-scale training
        p = min(self.PERIOD, s)
        pattern = jax.random.randint(k1, (b, p), 0, v, dtype=jnp.int32)
        reps = -(-s // p)
        periodic = jnp.tile(pattern, (1, reps))[:, :s]
        noise = jax.random.randint(k2, (b, s), 0, v, dtype=jnp.int32)
        use_structure = jax.random.uniform(k3, (b, s)) < self.structure
        tokens = jnp.where(use_structure, periodic, noise).astype(jnp.int32)
        labels = jnp.concatenate(
            [tokens[:, 1:],
             jax.random.randint(k4, (b, 1), 0, v, dtype=jnp.int32)], axis=1)
        return {"tokens": tokens, "labels": labels}

    def __iter__(self) -> Iterator[Dict[str, jax.Array]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def batch_for_model(model, shape, step: int, seed: int = 0,
                    batch_override: Optional[int] = None):
    """Concrete batch matching model.input_specs (incl. stub modalities)."""
    specs = model.input_specs(shape, batch_override)
    b = batch_override or shape.global_batch
    out = {}
    if "tokens" in specs and shape.kind == "train":
        st = SyntheticTokens(model.cfg.vocab, shape.seq_len, b, seed)
        out.update(st.batch_at(step))
    elif "tokens" in specs:
        key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
        out["tokens"] = jax.random.randint(
            key, specs["tokens"].shape, 0, model.cfg.vocab, dtype=jnp.int32)
    for name in ("frames", "img_embeds"):
        if name in specs:
            key = jax.random.fold_in(jax.random.PRNGKey(seed + 99), step)
            out[name] = (jax.random.normal(key, specs[name].shape,
                                           jnp.float32)
                         / np.sqrt(specs[name].shape[-1]))
    return out
