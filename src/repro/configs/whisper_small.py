"""whisper-small — enc-dec audio backbone [arXiv:2212.04356; unverified].
12L d_model=768 12H (kv=12) d_ff=3072 vocab=51865. Conv/mel frontend is a
STUB: input_specs() provides precomputed frame embeddings (B, 1500, d)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="encdec", n_layers=12, d_model=768,
    n_heads=12, n_kv=12, head_dim=64, d_ff=3072, vocab=51865,
    enc_layers=12, enc_len=1500, param_dtype="bfloat16")
