"""grok-1-314b — 8-expert top-2 MoE [hf:xai-org/grok-1; unverified].
64L d_model=6144 48H (GQA kv=8) expert d_ff=32768 vocab=131072.
8 experts < 16 model shards -> shard the expert FFN dim (moe_shard='ffn')."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b", family="moe", n_layers=64, d_model=6144,
    n_heads=48, n_kv=8, head_dim=128, d_ff=32768, vocab=131072,
    moe_experts=8, moe_topk=2, moe_dff=32768, moe_cf=1.25,
    moe_groups=16,    # §Perf H2 carry-over: -10% memory / -19% collective
    moe_shard="ffn", param_dtype="bfloat16",
    rule_overrides={"experts": None, "expert_ffn": "model"})
