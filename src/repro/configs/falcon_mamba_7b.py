"""falcon-mamba-7b — attention-free Mamba-1 LM [arXiv:2410.05355;
unverified]. 64L d_model=4096 d_ff=0 vocab=65024, ssm_state=16."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm", n_layers=64, d_model=4096,
    n_heads=1, n_kv=1, head_dim=64, d_ff=0, vocab=65024,
    ssm_state=16, ssm_expand=2, ssm_conv=4, mamba_version=1,
    ssm_chunk=32,     # §Perf H1 iter-3: 8% less HBM traffic than Q=128
    param_dtype="bfloat16")
