"""Assigned-architecture configs (--arch <id>) + paper ESCG presets."""
from typing import Dict

from .base import (LONG_CONTEXT_FAMILIES, SHAPES, ModelConfig, ShapeConfig,
                   cell_is_runnable)


def _load() -> Dict[str, ModelConfig]:
    from . import (falcon_mamba_7b, granite_3_8b, grok_1_314b,
                   kimi_k2_1t_a32b, minitron_4b, pixtral_12b, qwen1_5_32b,
                   whisper_small, yi_9b, zamba2_7b)
    mods = [minitron_4b, granite_3_8b, qwen1_5_32b, yi_9b, pixtral_12b,
            falcon_mamba_7b, whisper_small, kimi_k2_1t_a32b, grok_1_314b,
            zamba2_7b]
    return {m.CONFIG.name: m.CONFIG for m in mods}


ARCHS: Dict[str, ModelConfig] = _load()


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]
