"""qwen1.5-32b — dense GQA LM with QKV bias [hf:Qwen; hf].
64L d_model=5120 40H (GQA kv=40) d_ff=27392 vocab=152064."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b", family="dense", n_layers=64, d_model=5120,
    n_heads=40, n_kv=40, head_dim=128, d_ff=27392, vocab=152064,
    qkv_bias=True, param_dtype="bfloat16")
