"""zamba2-7b — Mamba-2 blocks + SHARED attention block [arXiv:2411.15242;
unverified]. 81L d_model=3584 32H (MHA kv=32) d_ff=14336 vocab=32000,
ssm_state=64. The shared transformer block is applied after every
`attn_every` mamba blocks with reused weights (per-application KV cache)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid", n_layers=81, d_model=3584,
    n_heads=32, n_kv=32, head_dim=112, d_ff=14336, vocab=32000,
    ssm_state=64, ssm_expand=2, ssm_conv=4, mamba_version=2, attn_every=6,
    param_dtype="bfloat16")
