"""Model / run configuration dataclasses + the assigned input-shape grid."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 1e4
    tie_embeddings: bool = False
    # MoE
    moe_experts: int = 0
    moe_topk: int = 0
    moe_dff: int = 0
    moe_cf: float = 2.0            # capacity factor
    moe_groups: int = 4            # GShard token groups per device-batch
    moe_shard: str = "expert"      # 'expert' (EP) | 'ffn' (TP over expert dff)
    # SSM (mamba)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_heads: int = 0             # mamba2 heads (0 -> d_inner // 64)
    mamba_version: int = 1
    # hybrid (zamba2)
    attn_every: int = 6
    # enc-dec (whisper)
    enc_layers: int = 0
    enc_len: int = 1500
    # vlm (pixtral)
    vlm_prefix: int = 0            # image-token prefix length (stub embeds)
    # numerics / execution
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True
    attn_chunk: int = 1024         # kv-chunked attention above this seq len
    ssm_chunk: int = 128
    optimizer: str = "adamw"       # adamw | adafactor
    # per-arch logical-axis rule overrides (e.g. grok: ffn-sharded experts)
    rule_overrides: Dict[str, Optional[str]] = field(default_factory=dict)

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.family in ("ssm", "hybrid") and self.ssm_heads == 0:
            d_inner = self.d_model * self.ssm_expand
            object.__setattr__(self, "ssm_heads", max(1, d_inner // 64))

    @property
    def d_inner(self) -> int:
        return self.d_model * self.ssm_expand

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded up to a multiple of 256 for sharding (standard
        Megatron-style padding; loss slices logits back to `vocab`)."""
        return -(-self.vocab // 256) * 256

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        return self.replace(
            n_layers=max(2, min(4, self.n_layers)),
            d_model=64,
            n_heads=4, n_kv=min(self.n_kv, 2) if self.n_kv else 2,
            head_dim=16,
            d_ff=128, vocab=256,
            moe_experts=min(self.moe_experts, 4) or self.moe_experts,
            moe_topk=min(self.moe_topk, 2) or self.moe_topk,
            moe_dff=64 if self.moe_dff else 0,
            ssm_state=min(self.ssm_state, 8) if self.ssm_state else 0,
            ssm_heads=2 if self.family in ("ssm", "hybrid") else 0,
            enc_layers=2 if self.enc_layers else 0,
            enc_len=32 if self.enc_layers else 0,
            vlm_prefix=8 if self.vlm_prefix else 0,
            attn_every=2,
            param_dtype="float32", compute_dtype="float32",
            attn_chunk=64, ssm_chunk=16)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # 'train' | 'prefill' | 'decode'


# The assigned input-shape grid (one set for all 10 LM archs).
SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# long_500k needs sub-quadratic attention state; only SSM/hybrid archs run it
# (DESIGN.md §9) — pure full-attention archs record a documented skip.
LONG_CONTEXT_FAMILIES = ("ssm", "hybrid")


def cell_is_runnable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    if shape.name == "long_500k" and cfg.family not in LONG_CONTEXT_FAMILIES:
        return False, ("skipped: pure full-attention arch at 524288-token KV "
                       "decode (sub-quadratic state required; see DESIGN.md)")
    return True, ""
