"""kimi-k2-1t-a32b — trillion-parameter MoE [arXiv:2501.kimi2; unverified].
61L d_model=7168 64H (GQA kv=8) expert d_ff=2048 vocab=163840,
MoE 384 experts top-8. Optimizer: adafactor (AdamW fp32 moments for 1.04T
params exceed the 16 GB/chip v5e budget at 512 chips — see DESIGN.md)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe", n_layers=61, d_model=7168,
    n_heads=64, n_kv=8, head_dim=112, d_ff=2048, vocab=163840,
    moe_experts=384, moe_topk=8, moe_dff=2048, moe_cf=1.25,
    moe_groups=16,    # §Perf H2 iter-3: capacity C ∝ T/E; 16 groups cut
                      # dispatch traffic 2x and dispatch FLOPs 2.1x vs 4
    moe_shard="expert", optimizer="adafactor", param_dtype="bfloat16")
