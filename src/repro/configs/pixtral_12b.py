"""pixtral-12b — pixtral-ViT + mistral-nemo backbone [hf:mistralai;
unverified]. 40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072.
Vision frontend is a STUB: input_specs() provides precomputed patch
embeddings (vlm_prefix tokens) prepended to the text sequence."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b", family="vlm", n_layers=40, d_model=5120,
    n_heads=32, n_kv=8, head_dim=128, d_ff=14336, vocab=131072,
    vlm_prefix=1024, rope_theta=1e6, param_dtype="bfloat16")
