"""yi-9b — llama-arch GQA LM [arXiv:2403.04652; hf].
48L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="yi-9b", family="dense", n_layers=48, d_model=4096,
    n_heads=32, n_kv=4, head_dim=128, d_ff=11008, vocab=64000,
    param_dtype="bfloat16")
