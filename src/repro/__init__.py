"""repro — GPU-paper reproduction: Evolutionary Spatial Cyclic Games as a
multi-pod JAX framework (see DESIGN.md)."""
__version__ = "1.0.0"
