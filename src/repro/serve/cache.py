"""LRU compiled-engine cache (DESIGN.md §12).

Compilation is the serving tax: one `engines.build` + chunk trace costs
orders of magnitude more than the chunk it produces executes in at smoke
scale. The cache keys compiled state by ``(BucketKey, scenario_key)`` —
the bucket fixes every trace-shaping knob, the scenario hash fixes the
physics constants baked into the program — so any request stream that
revisits a (shape, physics) pair pays the trace exactly once until LRU
pressure evicts it.

Retrace detection: each entry snapshots the jit caches of its compiled
callables (``PjitFunction._cache_size``). The chunk callables are jitted
with ``static_argnames=('n_mcs',)``, so the FIRST batch that packs a new
step size legitimately grows the cache — the executor reports every
static length it runs (``note_chunk_length``) and ``note_run`` nets those
expected grows out. What remains — a previously-seen shape tracing again
on an entry that already served a batch — is a served-layer invariant
violation surfaced as the ``retraces`` counter (asserted zero by
tests/test_serve.py); legitimate new-length compiles are counted
separately as ``length_traces`` and their wall time is handed back to the
server so it lands in ``compile_s``, not ``run_s``."""
from __future__ import annotations

import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Set, Tuple

import numpy as np

from ..core.params import EscgParams
from .bucketing import BucketKey

__all__ = ["CompiledEngine", "EngineCache"]

CacheKey = Tuple[BucketKey, str]


def _jit_cache_size(fn: Any) -> int:
    size = getattr(fn, "_cache_size", None)
    return int(size()) if callable(size) else 0


@dataclass
class CompiledEngine:
    """Everything reusable across batches of one (bucket, scenario):
    params template, dominance matrix, execution kind, the jitted chunk /
    init / counts callables and the device placements they expect."""
    key: CacheKey
    params: EscgParams             # template: seed/mcs/trials vary per job
    dom: np.ndarray
    kind: str                      # 'pod' | 'vmap' | 'single'
    chunk_fn: Callable             # trial chunk (or simulate chunk: single)
    init_fn: Callable              # trial_keys -> (grids, keys) | k0 -> grid
    counts_fn: Callable            # grids -> (n, S+1) | grid -> (S+1,)
    pipe: Optional[object] = None  # ObsPipeline when observables stream
    built: Optional[object] = None  # BuiltEngine (pod / single kinds)
    pod_width: int = 1             # trial-axis padding multiple
    n_devices: int = 1             # devices a batch runs on (TrialResult)
    ring_sharding: Optional[object] = None
    jit_fns: Tuple[Any, ...] = ()  # callables watched for retraces
    build_s: float = 0.0           # wall time of the build (miss cost)
    runs: int = 0                  # batches served
    seen_chunk_lengths: Set[int] = field(default_factory=set)
    _trace_mark: int = 0
    _new_lengths: int = 0          # new static lengths since last note_run
    _new_trace_s: float = 0.0      # their trace+compile wall time

    def trace_count(self) -> int:
        return sum(_jit_cache_size(f) for f in self.jit_fns)

    def mark_traced(self) -> None:
        self._trace_mark = self.trace_count()

    def retraced(self) -> bool:
        """True when a jit cache grew since the last ``mark_traced``."""
        return self.trace_count() > self._trace_mark

    def note_chunk_length(self, m: int, wall_s: float = 0.0) -> bool:
        """Record one chunk call at static length ``m``; True when this
        entry had not traced that length yet. ``wall_s`` is the call's
        wall time (trace + compile dominate a first-use call — jit
        dispatch is async, so device execution lands in the later
        blocking read, not here)."""
        if m in self.seen_chunk_lengths:
            return False
        self.seen_chunk_lengths.add(m)
        self._new_lengths += 1
        self._new_trace_s += wall_s
        return True

    def consume_new_lengths(self) -> Tuple[int, float]:
        """(count, wall seconds) of new static chunk lengths recorded
        since the last call; resets both."""
        out = (self._new_lengths, self._new_trace_s)
        self._new_lengths, self._new_trace_s = 0, 0.0
        return out


@dataclass
class EngineCache:
    """Ordered-dict LRU over :class:`CompiledEngine` with accounting."""
    max_entries: int = 8
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    retraces: int = 0
    length_traces: int = 0         # legitimate new-chunk-length compiles
    _entries: "OrderedDict[CacheKey, CompiledEngine]" = field(
        default_factory=OrderedDict)

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: CacheKey) -> bool:
        return key in self._entries

    def get_or_build(self, key: CacheKey,
                     builder: Callable[[], CompiledEngine]
                     ) -> Tuple[CompiledEngine, bool]:
        """The cached entry for ``key``, building (and timing) on a miss.
        Returns ``(entry, hit)``; a hit moves the entry to MRU position."""
        entry = self._entries.get(key)
        if entry is not None:
            self._entries.move_to_end(key)
            self.hits += 1
            return entry, True
        self.misses += 1
        t0 = time.perf_counter()
        entry = builder()
        entry.build_s = time.perf_counter() - t0
        entry.key = key
        self._entries[key] = entry
        while len(self._entries) > self.max_entries:
            self._entries.popitem(last=False)
            self.evictions += 1
        return entry, False

    def note_run(self, entry: CompiledEngine) -> Tuple[int, float]:
        """Post-batch bookkeeping. The executor reports each static chunk
        length it ran (``note_chunk_length``); a first use of a new
        length is an EXPECTED jit-cache grow — mixed-budget packing is
        advertised behaviour — so the retrace counter only fires when the
        watched caches grew BEYOND those, i.e. a previously-seen shape
        traced again on an entry that had already served traffic (the
        first batch's traces are the expected compile, never a retrace).
        Returns ``(new_lengths, trace_s)`` so the caller can bill
        first-use chunk traces as compile time rather than run time."""
        new_lengths, trace_s = entry.consume_new_lengths()
        if entry.runs > 0:
            self.length_traces += new_lengths
            if entry.trace_count() > entry._trace_mark + new_lengths:
                self.retraces += 1
        entry.runs += 1
        entry.mark_traced()
        return new_lengths, trace_s

    def accounting(self) -> Dict[str, Any]:
        return {
            "entries": len(self._entries),
            "max_entries": self.max_entries,
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "retraces": self.retraces,
            "length_traces": self.length_traces,
            "hit_rate": (self.hits / (self.hits + self.misses)
                         if (self.hits + self.misses) else 0.0),
        }
