"""Packed batch executor (DESIGN.md §12): many requests, one device batch.

``run_packed`` advances every request of one (bucket, scenario) group in
a single trial batch on the pod axis. Bit-identity with a direct
``trials.run_trials`` call — the serving contract — falls out of three
repo invariants plus one scheduling rule:

* per-trial keys are ``fold_in(PRNGKey(seed), local_index)``, a pure
  function of the request's own seed — packing neighbours cannot perturb
  a trajectory (core/trials.py module docstring);
* trajectories and per-MCS alive masks are chunk-schedule invariant —
  only *where the host looks* depends on chunk boundaries, and all
  statistics here are per-MCS precise with explicit offsets;
* observable rows are flush-schedule invariant, capacity permitting
  (DESIGN.md §11) — the admission rail rejects capacities below a
  request's effective chunk, so no packing schedule ever wraps the ring.

The scheduling rule: each request ``j`` owns the boundary set its direct
run would visit — multiples of ``eff_j = max(1, min(chunk_mcs, mcs_j))``
capped at ``mcs_j`` — and the batch always advances to the NEAREST
boundary over the active requests. A request's stasis early-exit and its
``mcs_completed`` are evaluated only at its own boundaries, so both
reproduce the direct run exactly; between its boundaries the request
merely rides along (per-MCS stats are unaffected). The step size is
therefore ``<= min(eff_j)`` over active requests, which bounds every
ring flush below each request's capacity rail.

``run_single`` is the same contract for the non-vmappable single-lattice
engines (``sharded``): it replays ``simulation.simulate``'s loop line
for line against the entry's cached compiled chunk.
"""
from __future__ import annotations

import time
from bisect import bisect_right
from dataclasses import dataclass, field as dc_field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..core import engines, lattice, metrics
from ..core import observables as obs_mod
from ..core.params import EscgParams
from ..core.simulation import SimResult, build_chunk_fn, build_obs_chunk_fn
from ..core.trials import (POD_AXIS, TrialResult, _first_true_mcs,
                           build_trial_chunk, build_trial_obs_chunk,
                           fold_trial_keys, make_trial_init, pad_trials,
                           pod_sharding)
from .bucketing import Pending
from .cache import CompiledEngine

__all__ = ["engine_kind", "effective_chunk", "build_entry", "run_packed",
           "run_single"]

EmitFn = Callable[[Pending, Dict], None]


def engine_kind(engine: str) -> str:
    """Execution path of an engine: ``'pod'`` (composed pod x grid mesh),
    ``'vmap'`` (trial-vmapped) or ``'single'`` (one lattice at a time —
    the non-vmappable multi-device engines)."""
    caps = engines.get_engine(engine).caps
    if caps.pod_composable:
        return "pod"
    if caps.vmappable:
        return "vmap"
    return "single"


def effective_chunk(p: EscgParams, n_mcs: int) -> int:
    """The chunk length a direct driver run would use (run_trials /
    simulate both clamp the configured chunk to the MCS budget)."""
    return max(1, min(p.chunk_mcs, n_mcs))


def build_entry(p: EscgParams, dom: np.ndarray) -> CompiledEngine:
    """Compile the reusable state for one (bucket, scenario): the engine,
    the jitted chunk, the init closure and the device placements —
    everything a batch needs except the per-request seeds/budgets."""
    dom_j = jnp.asarray(dom, jnp.float32)
    kind = engine_kind(p.engine)
    obs_on = bool(p.observables)
    pipe = None

    if kind == "pod":
        built = engines.build(p, dom_j)
        if obs_on:
            chunk_fn, pipe = build_trial_obs_chunk(p, dom_j, built=built)
        else:
            chunk_fn = build_trial_chunk(p, dom_j, built=built)
        init_fn = make_trial_init(p, built.key_sharding,
                                  built.batch_sharding)
        counts_fn = jax.jit(jax.vmap(
            lambda g: metrics.counts(g, p.species)))
        return CompiledEngine(
            key=None, params=p, dom=np.asarray(dom), kind=kind,
            chunk_fn=chunk_fn, init_fn=init_fn, counts_fn=counts_fn,
            pipe=pipe, built=built, pod_width=built.pod_width,
            n_devices=built.batch_sharding.mesh.devices.size,
            ring_sharding=NamedSharding(built.key_sharding.mesh,
                                        P(None, POD_AXIS)),
            jit_fns=(chunk_fn, counts_fn))

    if kind == "vmap":
        caps = engines.get_engine(p.engine).caps
        sharding = pod_sharding(None if caps.trial_shardable else 1)
        n_dev = sharding.mesh.devices.size
        if obs_on:
            chunk_fn, pipe = build_trial_obs_chunk(p, dom_j)
        else:
            chunk_fn = build_trial_chunk(p, dom_j)
        init_fn = make_trial_init(p, sharding)
        counts_fn = jax.jit(jax.vmap(
            lambda g: metrics.counts(g, p.species)))
        return CompiledEngine(
            key=None, params=p, dom=np.asarray(dom), kind=kind,
            chunk_fn=chunk_fn, init_fn=init_fn, counts_fn=counts_fn,
            pipe=pipe, built=None, pod_width=n_dev, n_devices=n_dev,
            ring_sharding=NamedSharding(sharding.mesh, P(None, POD_AXIS)),
            jit_fns=(chunk_fn, counts_fn))

    built = engines.build(p, dom_j)
    if obs_on:
        chunk_fn, pipe = build_obs_chunk_fn(p, dom_j, built=built)
    else:
        chunk_fn = build_chunk_fn(p, dom_j, built=built)
    return CompiledEngine(
        key=None, params=p, dom=np.asarray(dom), kind="single",
        chunk_fn=chunk_fn, init_fn=None, counts_fn=None, pipe=pipe,
        built=built, pod_width=1,
        n_devices=(built.grid_sharding.mesh.devices.size
                   if built.grid_sharding is not None else 1),
        ring_sharding=None, jit_fns=(chunk_fn,))


# ----------------------------- packed batches ------------------------------ #

@dataclass
class _JobState:
    """Host-side streamed statistics of one request inside the batch —
    the per-request mirror of the accumulator block in run_trials."""
    pend: Pending
    sl: slice                    # this request's rows in the batch
    n: int
    n_mcs: int
    eff: int
    boundaries: List[int]        # ascending: direct-run chunk boundaries
    ext: np.ndarray
    stasis: np.ndarray
    surv: np.ndarray
    final_cnts: np.ndarray
    rows: List[np.ndarray] = dc_field(default_factory=list)
    kept: int = 0
    att: int = 0
    frozen_at: int = -1          # mcs_completed once finished

    def next_boundary(self, done: int) -> int:
        return self.boundaries[bisect_right(self.boundaries, done)]


def _job_boundaries(eff: int, n_mcs: int) -> List[int]:
    bs = list(range(eff, n_mcs, eff))
    bs.append(n_mcs)
    return bs


def run_packed(entry: CompiledEngine, pends: Sequence[Pending],
               emit: Optional[EmitFn] = None
               ) -> List[Tuple[Pending, TrialResult]]:
    """Run one packed batch; one ``TrialResult`` per request, each
    bit-identical to ``run_trials(req.scenario, req.n_trials, ...)``."""
    p = entry.params
    pipe = entry.pipe
    obs_on = pipe is not None
    s = p.species

    states: List[_JobState] = []
    off = 0
    for pend in pends:
        n = max(1, pend.req.n_trials)
        n_mcs = pend.n_mcs
        eff = effective_chunk(p, n_mcs)
        states.append(_JobState(
            pend=pend, sl=slice(off, off + n), n=n, n_mcs=n_mcs, eff=eff,
            boundaries=_job_boundaries(eff, n_mcs) if n_mcs else [],
            ext=np.zeros(0), stasis=np.zeros(0), surv=np.zeros(0),
            final_cnts=np.zeros(0)))
        off += n
    total = off
    n_pad = pad_trials(total, entry.pod_width)

    blocks = [fold_trial_keys(jax.random.PRNGKey(js.pend.params.seed),
                              js.n) for js in states]
    if n_pad > total:
        # padding trials are physics-identical ballast for the SPMD
        # partitioner — same accounting as run_trials' own padding
        blocks.append(fold_trial_keys(jax.random.PRNGKey(0),
                                      n_pad - total))
    grids, keys = entry.init_fn(jnp.concatenate(blocks, axis=0))

    init_cnts = np.asarray(entry.counts_fn(grids))
    for js in states:
        ic = init_cnts[js.sl]
        js.ext = np.where(ic[:, 1:] > 0, -1, 0).astype(np.int64)
        js.stasis = np.full(js.n, -1, np.int64)
        js.surv = ic[:, 1:] > 0
        js.final_cnts = ic
        if js.n_mcs == 0:
            js.frozen_at = 0

    ring = pos = None
    if obs_on:
        effs = [js.eff for js in states if js.frozen_at < 0]
        cap = obs_mod.ring_capacity(p, max(effs, default=1))
        ring, pos = obs_mod.ring_init(cap, (n_pad, pipe.width))
        ring = jax.device_put(ring, entry.ring_sharding)

    chunk_fn = entry.chunk_fn
    done = 0
    active = [js for js in states if js.frozen_at < 0]
    while active:
        nxt = min(js.next_boundary(done) for js in active)
        m = nxt - done
        # n_mcs is a static argname: first use of a new step size traces
        # a new chunk variant inside this call — time it so the cache can
        # net the expected jit-cache grow out of retrace detection and
        # the server can bill it as compile_s rather than run_s
        new_len = m not in entry.seen_chunk_lengths
        t_call = time.perf_counter() if new_len else 0.0
        if obs_on:
            grids, keys, ring, pos, cnts, alive, kept, att = chunk_fn(
                grids, keys, ring, pos, m)
        else:
            grids, keys, cnts, alive, kept, att = chunk_fn(grids, keys, m)
        if new_len:
            entry.note_chunk_length(m, time.perf_counter() - t_call)
        alive_h = np.asarray(alive)              # (n_pad, m, S) bool
        cnts_h = np.asarray(cnts)
        kept_h, att_h = np.asarray(kept), np.asarray(att)
        rows_h = (obs_mod.ring_flush(np.asarray(ring), done, done + m)
                  if obs_on else None)

        for js in active:
            a = alive_h[js.sl]
            js.final_cnts = cnts_h[js.sl]
            js.kept += int(kept_h[js.sl].sum())
            js.att += int(att_h[js.sl].sum())
            first_dead = _first_true_mcs(~a, done)
            js.ext = np.where((js.ext < 0) & (first_dead > 0),
                              first_dead, js.ext)
            first_st = _first_true_mcs(a.sum(axis=2) <= 1, done)
            js.stasis = np.where((js.stasis < 0) & (first_st > 0),
                                 first_st, js.stasis)
            js.surv = a[:, -1, :]
            if obs_on:
                js.rows.append(rows_h[:, js.sl, :])
            at_boundary = nxt in js.boundaries or nxt == js.n_mcs
            if at_boundary and (nxt == js.n_mcs
                                or (js.stasis >= 0).all()):
                js.frozen_at = nxt
            if emit is not None:
                ev = {"mcs": nxt,
                      "in_stasis": int((js.stasis >= 0).sum()),
                      "n_trials": js.n, "done": js.frozen_at >= 0}
                if obs_on:
                    ev["observables"] = pipe.split(
                        np.moveaxis(rows_h[:, js.sl, :], 0, 1))
                emit(js.pend, ev)
        done = nxt
        active = [js for js in active if js.frozen_at < 0]

    out = []
    for js in states:
        observables = {}
        if obs_on and js.rows:
            rows = np.concatenate(js.rows, axis=0)   # (T, n, W)
            observables = pipe.split(np.moveaxis(rows, 0, 1))
        out.append((js.pend, TrialResult(
            survival=js.surv.astype(bool),
            densities=js.final_cnts / p.n_cells,
            stasis_mcs=js.stasis,
            extinction_mcs=js.ext,
            mcs_completed=js.frozen_at,
            kept_fraction=(js.kept / js.att) if js.att else 1.0,
            n_trials=js.n,
            n_devices=entry.n_devices,
            observables=observables)))
    return out


# --------------------------- single-lattice path --------------------------- #

def run_single(entry: CompiledEngine, pend: Pending,
               emit: Optional[EmitFn] = None) -> SimResult:
    """The ``simulate`` loop against the cached compiled chunk, for
    engines that decompose one lattice across devices and cannot vmap
    over trials. Mirrors ``simulation.simulate`` exactly (same key
    split order, same eager init, same per-chunk stasis rule)."""
    p = pend.params
    pipe = entry.pipe
    obs_on = pipe is not None
    cell_dt = jnp.dtype(p.cell_dtype)

    key = jax.random.PRNGKey(p.seed)
    key, k0 = jax.random.split(key)
    grid0 = lattice.init_grid(k0, p.height, p.length, p.species, p.empty,
                              dtype=cell_dt)
    grid = jnp.asarray(grid0, cell_dt)
    if entry.built is not None and entry.built.grid_sharding is not None:
        grid = jax.device_put(grid, entry.built.grid_sharding)

    n_mcs_total = pend.n_mcs
    ring = pos = None
    rows_all: List[np.ndarray] = []
    if obs_on:
        max_chunk = effective_chunk(p, max(1, n_mcs_total))
        cap = obs_mod.ring_capacity(p, max_chunk)
        if cap < max_chunk:
            raise ValueError(
                f"obs_capacity {cap} < chunk rows {max_chunk}: the "
                "single-lattice path flushes once per chunk (0 = auto)")
        ring, pos = obs_mod.ring_init(cap, (pipe.width,))

    chunk_fn = entry.chunk_fn
    hist = [np.asarray(metrics.counts(grid, p.species))]
    mcs_done, stasis_mcs = 0, -1
    kept_total = att_total = 0

    while mcs_done < n_mcs_total:
        m = min(p.chunk_mcs, n_mcs_total - mcs_done)
        # same static-n_mcs accounting as run_packed: a budget that is
        # not a chunk multiple traces one extra tail-length variant
        new_len = m not in entry.seen_chunk_lengths
        t_call = time.perf_counter() if new_len else 0.0
        if obs_on:
            grid, key, ring, pos, kept, att = chunk_fn(grid, key, ring,
                                                       pos, m)
        else:
            grid, key, cnts, kept, att = chunk_fn(grid, key, m)
        if new_len:
            entry.note_chunk_length(m, time.perf_counter() - t_call)
        if obs_on:
            rows_h = obs_mod.ring_flush(np.asarray(ring), mcs_done,
                                        mcs_done + m)
            rows_all.append(rows_h)
            cnts_h = pipe.counts_from_rows(rows_h, p.species)
        else:
            cnts_h = np.asarray(cnts)
        hist.append(cnts_h)
        kept_total += int(kept)
        att_total += int(att)
        mcs_done += m
        alive = (cnts_h[:, 1:] > 0).sum(axis=1)
        if stasis_mcs < 0 and np.any(alive <= 1):
            stasis_mcs = mcs_done - m + int(np.argmax(alive <= 1)) + 1
        if emit is not None:
            emit(pend, {"mcs": mcs_done,
                        "in_stasis": int(stasis_mcs >= 0),
                        "n_trials": 1,
                        "done": (stasis_mcs >= 0
                                 or mcs_done >= n_mcs_total)})
        if stasis_mcs >= 0:
            break

    densities = (np.concatenate([hist[0][None, :]] + hist[1:], axis=0)
                 / p.n_cells)
    observables = {"densities": densities}
    if obs_on and rows_all:
        streams = pipe.split(np.concatenate(rows_all, axis=0))
        streams["densities"] = densities
        observables = streams
    return SimResult(
        grid=np.asarray(grid), observables=observables,
        mcs_completed=mcs_done, stasis_mcs=stasis_mcs,
        kept_fraction=(kept_total / att_total) if att_total else 1.0)
