"""ESCG serving layer (DESIGN.md §12) — the batch library as a resident
scenario server.

The ROADMAP's north star is serving heavy ESCG traffic: many users
submitting heterogeneous ``(scenario, lattice, mcs, trials)`` requests
against one long-lived process. This package turns ``core.trials`` /
``core.simulation`` into that service:

* :mod:`protocol` — the ``SimRequest`` / ``SimResponse`` dataclass
  protocol with a JSON wire format;
* :mod:`bucketing` — compiled-shape bucket keys and the admission queue
  that packs same-bucket requests onto the pod axis of one mesh;
* :mod:`cache` — the LRU compiled-engine cache (hit / miss / retrace
  counters) proving repeat traffic never re-traces;
* :mod:`executor` — the packed batch executor: one device batch, many
  requests, per-request chunk-boundary accounting bit-identical to a
  direct ``run_trials`` / ``simulate`` call;
* :mod:`server` — :class:`~repro.serve.server.ScenarioServer`, the
  in-process callable handle (admission → scheduling → responses);
* :mod:`httpd` — a stdlib ``http.server`` adapter behind a flag;
* :mod:`loadgen` — JSONL trace replay (synthetic generator included)
  emitting throughput/latency reports compatible with ``bench_gate``'s
  schema machinery.

Transport is in-process first: tier-1 tests and the CI serve-smoke job
drive the callable handle directly; the HTTP adapter wraps the same
object without touching scheduling.
"""
from .protocol import SimRequest, SimResponse  # noqa: F401
from .server import ScenarioServer  # noqa: F401
