"""Optional stdlib HTTP adapter over :class:`ScenarioServer`.

Transport is in-process first (DESIGN.md §12): this module is a thin
JSON shim for clients that cannot import the package — it owns no
scheduling state and every route delegates to the same server object the
in-process handle uses. Enabled behind the ``escg_serve --http`` flag.

Routes (all JSON):

* ``POST /submit``  — one request object or a list; replies with ids
* ``POST /drain``   — run the scheduler until the queue is empty
* ``POST /step``    — run exactly one batch
* ``POST /ack?id=<rid>``       — release one retained response
* ``GET /response?id=<rid>``   — the response for one request
* ``GET /progress?id=<rid>``   — per-chunk progress events
* ``GET /accounting``          — serving counters
* ``GET /healthz``             — liveness
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional, Tuple
from urllib.parse import parse_qs, urlparse

from .server import ScenarioServer

__all__ = ["serve_http"]


def _json_default(o):
    import numpy as np
    if isinstance(o, np.ndarray):
        return o.tolist()
    if isinstance(o, (np.integer, np.floating, np.bool_)):
        return o.item()
    raise TypeError(f"not JSON serializable: {type(o).__name__}")


def _make_handler(server: ScenarioServer):
    class Handler(BaseHTTPRequestHandler):
        def log_message(self, fmt, *args):   # quiet by default
            pass

        def _reply(self, code: int, payload) -> None:
            body = json.dumps(payload, default=_json_default).encode()
            self.send_response(code)
            self.send_header("Content-Type", "application/json")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def _read_json(self):
            n = int(self.headers.get("Content-Length", 0))
            return json.loads(self.rfile.read(n) or b"null")

        def do_GET(self):
            url = urlparse(self.path)
            if url.path == "/healthz":
                return self._reply(200, {"ok": True})
            if url.path == "/accounting":
                return self._reply(200, server.accounting())
            rid = parse_qs(url.query).get("id", [""])[0]
            if url.path == "/response":
                resp = server.response(rid)
                if resp is None:
                    return self._reply(404, {"error": f"no response for "
                                                      f"id {rid!r}"})
                return self._reply(200, resp.to_wire())
            if url.path == "/progress":
                return self._reply(200, {"id": rid,
                                         "events": server.progress(rid)})
            return self._reply(404, {"error": f"unknown path {url.path}"})

        def do_POST(self):
            url = urlparse(self.path)
            if url.path == "/ack":
                rid = parse_qs(url.query).get("id", [""])[0]
                resp = server.ack(rid)
                if resp is None:
                    return self._reply(404, {"error": f"no response for "
                                                      f"id {rid!r}"})
                return self._reply(200, resp.to_wire())
            if self.path == "/submit":
                try:
                    payload = self._read_json()
                except (ValueError, json.JSONDecodeError) as e:
                    return self._reply(400, {"error": str(e)})
                reqs = payload if isinstance(payload, list) else [payload]
                ids = [server.submit(r) for r in reqs]
                return self._reply(200, {"ids": ids})
            if self.path == "/drain":
                return self._reply(200, {"answered": server.drain()})
            if self.path == "/step":
                return self._reply(200, {"answered": server.step()})
            return self._reply(404, {"error": f"unknown path {self.path}"})

    return Handler


def serve_http(server: ScenarioServer, host: str = "127.0.0.1",
               port: int = 0, *, background: bool = False
               ) -> Tuple[ThreadingHTTPServer, Optional[threading.Thread]]:
    """Bind the HTTP adapter; ``port=0`` picks a free port (read it back
    from ``httpd.server_address``). With ``background=True`` the accept
    loop runs on a daemon thread and the pair ``(httpd, thread)`` is
    returned immediately — call ``httpd.shutdown()`` to stop."""
    httpd = ThreadingHTTPServer((host, port), _make_handler(server))
    if not background:
        try:
            httpd.serve_forever()
        finally:
            httpd.server_close()
        return httpd, None
    thread = threading.Thread(target=httpd.serve_forever, daemon=True)
    thread.start()
    return httpd, thread
