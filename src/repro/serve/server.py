"""ScenarioServer — the in-process serving handle (DESIGN.md §12).

One long-lived object owns the admission queue, the compiled-engine
cache and the scheduler loop:

``submit`` parses + resolves + validates a request (invalid requests get
an immediate error response — they are *answered*, never dropped),
stamps the admission time and enqueues it under its (bucket,
scenario_key) group. ``step`` drains one batch: pop a group by the
age/occupancy policy, hit or build the compiled engine, run the packed
executor (or the single-lattice path) and materialize one
``SimResponse`` per request with per-request queue / compile / run
latency. ``drain`` steps until the queue is empty. The whole object is
guarded by one reentrant lock, so the threaded HTTP adapter can share
it; execution itself is deliberately serial — there is one accelerator.

Per-chunk progress events (``progress(id)``) stream the boundary-level
state of a running request: MCS reached, trials in stasis, and — when
observables are on — that chunk's finalized observable rows.

Retention: a resident server must not grow without bound, so answered
responses (and their progress events) are retained up to
``max_responses`` — beyond that the oldest answered response is evicted
(pending requests are never touched, and ``accounting()['responded']``
counts cumulatively, so eviction never reads as a drop). Clients that
want deterministic memory bounds ``ack(id)`` responses to release them
eagerly. Latency statistics are running aggregates (count / mean / max
over the whole lifetime, percentiles over a bounded recent window), not
raw per-request lists.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Any, Dict, List, Optional, Sequence, Union

import jax
import numpy as np

from ..core import dominance as dom_mod
from ..core import observables as obs_mod
from ..core.scenarios import resolve_config, scenario_key
from .bucketing import AdmissionQueue, Pending, bucket_key
from .cache import EngineCache
from .executor import (build_entry, effective_chunk, engine_kind,
                       run_packed, run_single)
from .protocol import SimRequest, SimResponse, parse_request

__all__ = ["ScenarioServer"]


class _LatencyAgg:
    """Bounded-memory latency statistics for a long-lived server: count,
    running mean and max cover the whole lifetime; percentiles come from
    the last ``window`` samples (a deque, so memory is O(window) however
    long the server runs)."""

    def __init__(self, window: int = 1024) -> None:
        self.count = 0
        self.mean = 0.0
        self.max = 0.0
        self.recent: "deque[float]" = deque(maxlen=window)

    def add(self, x: float) -> None:
        self.count += 1
        self.mean += (x - self.mean) / self.count
        self.max = max(self.max, x)
        self.recent.append(x)

    def stats(self) -> Dict[str, float]:
        if not self.count:
            return {"count": 0}
        a = np.asarray(self.recent, dtype=np.float64)
        return {"count": self.count, "mean_s": float(self.mean),
                "p50_s": float(np.percentile(a, 50)),
                "p95_s": float(np.percentile(a, 95)),
                "max_s": float(self.max)}


class ScenarioServer:
    """Continuously-batched ESCG scenario server (in-process transport).

    ``max_batch_trials`` caps the trials packed into one device batch;
    ``cache_entries`` bounds the LRU compiled-engine cache;
    ``max_responses`` bounds retained answered responses (oldest evicted
    first — see the module docstring's retention policy). Typical use::

        srv = ScenarioServer()
        rid = srv.submit({"scenario": "park3", "n_trials": 4,
                          "run": {"mcs": 200, "length": 64, "height": 64}})
        srv.drain()
        resp = srv.response(rid)     # resp.result is a TrialResult
    """

    def __init__(self, max_batch_trials: int = 64,
                 cache_entries: int = 8,
                 max_responses: int = 4096) -> None:
        self.max_batch_trials = int(max_batch_trials)
        self.max_responses = max(1, int(max_responses))
        self._queue = AdmissionQueue()
        self._cache = EngineCache(max_entries=int(cache_entries))
        self._lock = threading.RLock()
        self._responses: Dict[str, SimResponse] = {}
        self._events: Dict[str, List[dict]] = {}
        self._order: List[str] = []      # response ids in submit order
        self._seq = 0
        self._n_requests = 0
        self._n_responded = 0            # cumulative (survives eviction)
        self._n_errors = 0
        self._n_evicted = 0
        self._n_batches = 0
        self._n_packed_trials = 0
        self._lat_total = _LatencyAgg()
        self._lat_queue = _LatencyAgg()
        self._lat_run = _LatencyAgg()

    # ------------------------------ admission -------------------------- #

    def submit(self, request: Union[str, dict, SimRequest]) -> str:
        """Admit one request; returns its response id. Requests that fail
        parsing/resolution/validation are answered immediately with an
        error response under the same id (never silently dropped)."""
        with self._lock:
            self._seq += 1
            seq = self._seq
            self._n_requests += 1
            rid = ""
            try:
                req = parse_request(request)
                rid = req.id or f"req-{seq}"
                if rid in self._responses or any(rid == i for i in
                                                 self._order):
                    # answer under a fresh id: clobbering the original
                    # response would silently drop one of the two
                    rid = f"{rid}#dup{seq}"
                    raise ValueError(f"duplicate request id {req.id!r}")
                req = dataclasses.replace(req, id=rid)
                pend = self._admit(seq, req)
            except Exception as e:  # answered, not dropped
                rid = rid or f"req-{seq}"
                self._order.append(rid)
                self._respond(SimResponse(id=rid, ok=False, kind="error",
                                          error=str(e)))
                return rid
            self._order.append(rid)
            self._queue.push(pend)
            return rid

    def _admit(self, seq: int, req: SimRequest) -> Pending:
        if req.n_trials < 1:
            raise ValueError("n_trials must be >= 1")
        params, dom = resolve_config(req.scenario, None, req.engine,
                                     req.run)
        p = params.validate()
        if dom is None:
            dom = dom_mod.circulant(p.species)
        n_dev = jax.device_count()
        for knob, layout in (("mesh_shape", p.mesh_shape),
                             ("shard_grid", p.shard_grid)):
            if layout is not None:
                need = int(np.prod(np.asarray(layout)))
                if need > n_dev:
                    raise ValueError(
                        f"{knob} {tuple(layout)} needs {need} devices but "
                        f"this host has {n_dev}: the engine build would "
                        "fail, so the request is rejected at admission")
        kind = engine_kind(p.engine)
        if kind == "single" and req.n_trials != 1:
            raise ValueError(
                f"engine {p.engine!r} is not vmappable: the server runs "
                "it on the single-lattice path, one trial per request "
                "(submit n_trials separate requests, or pick a "
                "trial-shardable engine)")
        sched = None
        if p.observables:
            eff = effective_chunk(p, max(1, p.mcs))
            if p.obs_capacity and p.obs_capacity < eff:
                raise ValueError(
                    f"obs_capacity {p.obs_capacity} < effective chunk "
                    f"{eff}: the server's bit-identity contract forbids "
                    "lossy ring wraparound (0 = auto-size)")
            if p.k_mcs > 1 and any(
                    not s.from_counts
                    for s in obs_mod.build_pipeline(p).specs):
                # lag-held rows depend on launch-group boundaries: only
                # identical MCS schedules may share a batch (bucketing.py)
                sched = p.mcs
        return Pending(seq=seq, req=req, params=p, dom=np.asarray(dom),
                       bucket=bucket_key(p), scenario_key=scenario_key(
                           req.scenario),
                       kind=kind, n_mcs=p.mcs, sched=sched)

    # ------------------------------ scheduling ------------------------- #

    def step(self) -> int:
        """Drain ONE batch from the queue; returns the number of requests
        answered (0 when idle)."""
        with self._lock:
            popped = self._queue.pop_batch(self.max_batch_trials)
            if popped is None:
                return 0
            (bucket, skey, _sched), pends = popped
            t_start = t_run = time.perf_counter()
            first = pends[0]
            entry = None
            hit = False
            compile_s = 0.0
            try:
                # inside the try: a failed engine build (mesh infeasible
                # on this host, OOM, ...) must still ANSWER every popped
                # request — the serving contract is answered, never
                # dropped, and drain() must not raise
                entry, hit = self._cache.get_or_build(
                    (bucket, skey),
                    lambda: build_entry(first.params, first.dom))
                compile_s = 0.0 if hit else entry.build_s
                t_run = time.perf_counter()
                if entry.kind == "single":
                    results = [(pd, run_single(entry, pd, emit=self._emit))
                               for pd in pends]
                    kind = "single"
                else:
                    results = run_packed(entry, pends, emit=self._emit)
                    kind = "trials"
            except Exception as e:
                now = time.perf_counter()
                if entry is None:      # build failed: all time is compile
                    compile_s, run_s = now - t_start, 0.0
                else:
                    run_s = now - t_run
                    _, trace_s = self._cache.note_run(entry)
                    compile_s += trace_s
                    run_s = max(0.0, run_s - trace_s)
                for pd in pends:
                    self._respond(SimResponse(
                        id=pd.req.id, ok=False, kind="error",
                        error=str(e),
                        timing={"queue_s": t_start - pd.t_submit,
                                "compile_s": compile_s, "run_s": run_s},
                        cache_hit=hit, bucket=bucket.short(),
                        scenario_key=skey))
                return len(pends)
            run_s = time.perf_counter() - t_run
            # a first use of a new packed step size traces a new chunk
            # variant inside the run window: bill it as compile time
            _, trace_s = self._cache.note_run(entry)
            compile_s += trace_s
            run_s = max(0.0, run_s - trace_s)
            self._n_batches += 1
            self._n_packed_trials += sum(max(1, pd.req.n_trials)
                                         for pd in pends)
            for pd, res in results:
                queue_s = t_start - pd.t_submit
                self._lat_queue.add(queue_s)
                self._lat_run.add(run_s)
                self._lat_total.add(time.perf_counter() - pd.t_submit)
                self._respond(SimResponse(
                    id=pd.req.id, ok=True, kind=kind, result=res,
                    timing={"queue_s": queue_s, "compile_s": compile_s,
                            "run_s": run_s},
                    cache_hit=hit, bucket=bucket.short(),
                    scenario_key=skey))
            return len(pends)

    def drain(self) -> int:
        """Step until the queue is empty; total requests answered."""
        n = 0
        while True:
            k = self.step()
            if not k:
                return n
            n += k

    def serve(self, requests: Sequence[Union[str, dict, SimRequest]]
              ) -> List[SimResponse]:
        """Submit-all + drain convenience: responses in submit order."""
        ids = [self.submit(r) for r in requests]
        self.drain()
        out = []
        for i in ids:
            resp = self._responses.get(i)
            if resp is None:
                raise RuntimeError(
                    f"response {i!r} was evicted before collection: this "
                    f"wave exceeded max_responses={self.max_responses}; "
                    "raise it or replay in smaller waves")
            out.append(resp)
        return out

    def __call__(self, request: Union[str, dict, SimRequest]
                 ) -> SimResponse:
        """One-shot handle: submit a single request and run it now."""
        return self.serve([request])[0]

    # ------------------------------ responses -------------------------- #

    def _respond(self, resp: SimResponse) -> None:
        if not resp.ok:
            self._n_errors += 1
        self._n_responded += 1
        self._responses[resp.id] = resp
        # retention: evict the oldest ANSWERED response (and its events)
        # past max_responses; ids still pending in _order are skipped
        while len(self._responses) > self.max_responses:
            for i, rid in enumerate(self._order):
                if rid in self._responses:
                    del self._responses[rid]
                    self._events.pop(rid, None)
                    del self._order[i]
                    self._n_evicted += 1
                    break
            else:
                break

    def _emit(self, pend: Pending, event: dict) -> None:
        self._events.setdefault(pend.req.id, []).append(event)

    def response(self, rid: str) -> Optional[SimResponse]:
        with self._lock:
            return self._responses.get(rid)

    def ack(self, rid: str) -> Optional[SimResponse]:
        """Acknowledge one response: returns it (None when unknown or
        already released) and frees its retained result + events, so a
        long-lived client can bound the server's memory deterministically
        instead of waiting for LRU eviction."""
        with self._lock:
            resp = self._responses.pop(rid, None)
            if resp is not None:
                self._events.pop(rid, None)
                try:
                    self._order.remove(rid)
                except ValueError:
                    pass
            return resp

    def responses(self) -> List[SimResponse]:
        """All responses so far, in submit order."""
        with self._lock:
            return [self._responses[i] for i in self._order
                    if i in self._responses]

    def progress(self, rid: str) -> List[dict]:
        """Per-chunk streamed events for one request (empty until its
        batch starts running)."""
        with self._lock:
            return list(self._events.get(rid, ()))

    # ------------------------------ accounting ------------------------- #

    def accounting(self) -> Dict[str, Any]:
        """Serving counters: every admitted request is either pending,
        answered ok, or answered with an error — ``dropped`` (admitted
        but never answered while the queue is empty) must be zero.
        ``responded`` counts cumulatively; ``retained`` is how many
        responses are currently held (``max_responses`` bound), so
        acking or evicting a response never reads as a drop."""
        with self._lock:
            pending = len(self._queue)
            return {
                "requests": self._n_requests,
                "responded": self._n_responded,
                "errors": self._n_errors,
                "pending": pending,
                "dropped": (self._n_requests - self._n_responded
                            - pending),
                "retained": len(self._responses),
                "evicted": self._n_evicted,
                "batches": self._n_batches,
                "packed_trials": self._n_packed_trials,
                "queue_depth": self._queue.depth(),
                "cache": self._cache.accounting(),
                "latency": {
                    "total": self._lat_total.stats(),
                    "queue": self._lat_queue.stats(),
                    "run": self._lat_run.stats(),
                },
            }
