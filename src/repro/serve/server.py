"""ScenarioServer — the in-process serving handle (DESIGN.md §12).

One long-lived object owns the admission queue, the compiled-engine
cache and the scheduler loop:

``submit`` parses + resolves + validates a request (invalid requests get
an immediate error response — they are *answered*, never dropped),
stamps the admission time and enqueues it under its (bucket,
scenario_key) group. ``step`` drains one batch: pop a group by the
age/occupancy policy, hit or build the compiled engine, run the packed
executor (or the single-lattice path) and materialize one
``SimResponse`` per request with per-request queue / compile / run
latency. ``drain`` steps until the queue is empty. The whole object is
guarded by one reentrant lock, so the threaded HTTP adapter can share
it; execution itself is deliberately serial — there is one accelerator.

Per-chunk progress events (``progress(id)``) stream the boundary-level
state of a running request: MCS reached, trials in stasis, and — when
observables are on — that chunk's finalized observable rows.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Dict, List, Optional, Sequence, Union

import numpy as np

from ..core import dominance as dom_mod
from ..core import observables as obs_mod
from ..core.scenarios import resolve_config, scenario_key
from .bucketing import AdmissionQueue, Pending, bucket_key
from .cache import EngineCache
from .executor import (build_entry, effective_chunk, engine_kind,
                       run_packed, run_single)
from .protocol import SimRequest, SimResponse, parse_request

__all__ = ["ScenarioServer"]


def _latency_stats(xs: List[float]) -> Dict[str, float]:
    if not xs:
        return {"count": 0}
    a = np.asarray(xs, dtype=np.float64)
    return {"count": int(a.size), "mean_s": float(a.mean()),
            "p50_s": float(np.percentile(a, 50)),
            "p95_s": float(np.percentile(a, 95)),
            "max_s": float(a.max())}


class ScenarioServer:
    """Continuously-batched ESCG scenario server (in-process transport).

    ``max_batch_trials`` caps the trials packed into one device batch;
    ``cache_entries`` bounds the LRU compiled-engine cache. Typical use::

        srv = ScenarioServer()
        rid = srv.submit({"scenario": "park3", "n_trials": 4,
                          "run": {"mcs": 200, "length": 64, "height": 64}})
        srv.drain()
        resp = srv.response(rid)     # resp.result is a TrialResult
    """

    def __init__(self, max_batch_trials: int = 64,
                 cache_entries: int = 8) -> None:
        self.max_batch_trials = int(max_batch_trials)
        self._queue = AdmissionQueue()
        self._cache = EngineCache(max_entries=int(cache_entries))
        self._lock = threading.RLock()
        self._responses: Dict[str, SimResponse] = {}
        self._events: Dict[str, List[dict]] = {}
        self._order: List[str] = []      # response ids in submit order
        self._seq = 0
        self._n_requests = 0
        self._n_errors = 0
        self._n_batches = 0
        self._n_packed_trials = 0
        self._lat_total: List[float] = []
        self._lat_queue: List[float] = []
        self._lat_run: List[float] = []

    # ------------------------------ admission -------------------------- #

    def submit(self, request: Union[str, dict, SimRequest]) -> str:
        """Admit one request; returns its response id. Requests that fail
        parsing/resolution/validation are answered immediately with an
        error response under the same id (never silently dropped)."""
        with self._lock:
            self._seq += 1
            seq = self._seq
            self._n_requests += 1
            rid = ""
            try:
                req = parse_request(request)
                rid = req.id or f"req-{seq}"
                if rid in self._responses or any(rid == i for i in
                                                 self._order):
                    # answer under a fresh id: clobbering the original
                    # response would silently drop one of the two
                    rid = f"{rid}#dup{seq}"
                    raise ValueError(f"duplicate request id {req.id!r}")
                req = dataclasses.replace(req, id=rid)
                pend = self._admit(seq, req)
            except Exception as e:  # answered, not dropped
                rid = rid or f"req-{seq}"
                self._order.append(rid)
                self._respond(SimResponse(id=rid, ok=False, kind="error",
                                          error=str(e)))
                return rid
            self._order.append(rid)
            self._queue.push(pend)
            return rid

    def _admit(self, seq: int, req: SimRequest) -> Pending:
        if req.n_trials < 1:
            raise ValueError("n_trials must be >= 1")
        params, dom = resolve_config(req.scenario, None, req.engine,
                                     req.run)
        p = params.validate()
        if dom is None:
            dom = dom_mod.circulant(p.species)
        kind = engine_kind(p.engine)
        if kind == "single" and req.n_trials != 1:
            raise ValueError(
                f"engine {p.engine!r} is not vmappable: the server runs "
                "it on the single-lattice path, one trial per request "
                "(submit n_trials separate requests, or pick a "
                "trial-shardable engine)")
        sched = None
        if p.observables:
            eff = effective_chunk(p, max(1, p.mcs))
            if p.obs_capacity and p.obs_capacity < eff:
                raise ValueError(
                    f"obs_capacity {p.obs_capacity} < effective chunk "
                    f"{eff}: the server's bit-identity contract forbids "
                    "lossy ring wraparound (0 = auto-size)")
            if p.k_mcs > 1 and any(
                    not s.from_counts
                    for s in obs_mod.build_pipeline(p).specs):
                # lag-held rows depend on launch-group boundaries: only
                # identical MCS schedules may share a batch (bucketing.py)
                sched = p.mcs
        return Pending(seq=seq, req=req, params=p, dom=np.asarray(dom),
                       bucket=bucket_key(p), scenario_key=scenario_key(
                           req.scenario),
                       kind=kind, n_mcs=p.mcs, sched=sched)

    # ------------------------------ scheduling ------------------------- #

    def step(self) -> int:
        """Drain ONE batch from the queue; returns the number of requests
        answered (0 when idle)."""
        with self._lock:
            popped = self._queue.pop_batch(self.max_batch_trials)
            if popped is None:
                return 0
            (bucket, skey, _sched), pends = popped
            t_start = time.perf_counter()
            first = pends[0]
            entry, hit = self._cache.get_or_build(
                (bucket, skey),
                lambda: build_entry(first.params, first.dom))
            compile_s = 0.0 if hit else entry.build_s
            t_run = time.perf_counter()
            try:
                if entry.kind == "single":
                    results = [(pd, run_single(entry, pd, emit=self._emit))
                               for pd in pends]
                    kind = "single"
                else:
                    results = run_packed(entry, pends, emit=self._emit)
                    kind = "trials"
            except Exception as e:
                run_s = time.perf_counter() - t_run
                self._cache.note_run(entry)
                for pd in pends:
                    self._respond(SimResponse(
                        id=pd.req.id, ok=False, kind="error",
                        error=str(e),
                        timing={"queue_s": t_start - pd.t_submit,
                                "compile_s": compile_s, "run_s": run_s},
                        cache_hit=hit, bucket=bucket.short(),
                        scenario_key=skey))
                return len(pends)
            run_s = time.perf_counter() - t_run
            self._cache.note_run(entry)
            self._n_batches += 1
            self._n_packed_trials += sum(max(1, pd.req.n_trials)
                                         for pd in pends)
            for pd, res in results:
                queue_s = t_start - pd.t_submit
                self._lat_queue.append(queue_s)
                self._lat_run.append(run_s)
                self._lat_total.append(time.perf_counter() - pd.t_submit)
                self._respond(SimResponse(
                    id=pd.req.id, ok=True, kind=kind, result=res,
                    timing={"queue_s": queue_s, "compile_s": compile_s,
                            "run_s": run_s},
                    cache_hit=hit, bucket=bucket.short(),
                    scenario_key=skey))
            return len(pends)

    def drain(self) -> int:
        """Step until the queue is empty; total requests answered."""
        n = 0
        while True:
            k = self.step()
            if not k:
                return n
            n += k

    def serve(self, requests: Sequence[Union[str, dict, SimRequest]]
              ) -> List[SimResponse]:
        """Submit-all + drain convenience: responses in submit order."""
        ids = [self.submit(r) for r in requests]
        self.drain()
        return [self._responses[i] for i in ids]

    def __call__(self, request: Union[str, dict, SimRequest]
                 ) -> SimResponse:
        """One-shot handle: submit a single request and run it now."""
        return self.serve([request])[0]

    # ------------------------------ responses -------------------------- #

    def _respond(self, resp: SimResponse) -> None:
        if not resp.ok:
            self._n_errors += 1
        self._responses[resp.id] = resp

    def _emit(self, pend: Pending, event: dict) -> None:
        self._events.setdefault(pend.req.id, []).append(event)

    def response(self, rid: str) -> Optional[SimResponse]:
        with self._lock:
            return self._responses.get(rid)

    def responses(self) -> List[SimResponse]:
        """All responses so far, in submit order."""
        with self._lock:
            return [self._responses[i] for i in self._order
                    if i in self._responses]

    def progress(self, rid: str) -> List[dict]:
        """Per-chunk streamed events for one request (empty until its
        batch starts running)."""
        with self._lock:
            return list(self._events.get(rid, ()))

    # ------------------------------ accounting ------------------------- #

    def accounting(self) -> Dict[str, Any]:
        """Serving counters: every admitted request is either pending,
        answered ok, or answered with an error — ``dropped`` (admitted
        but never answered while the queue is empty) must be zero."""
        with self._lock:
            pending = len(self._queue)
            responded = len(self._responses)
            return {
                "requests": self._n_requests,
                "responded": responded,
                "errors": self._n_errors,
                "pending": pending,
                "dropped": self._n_requests - responded - pending,
                "batches": self._n_batches,
                "packed_trials": self._n_packed_trials,
                "queue_depth": self._queue.depth(),
                "cache": self._cache.accounting(),
                "latency": {
                    "total": _latency_stats(self._lat_total),
                    "queue": _latency_stats(self._lat_queue),
                    "run": _latency_stats(self._lat_run),
                },
            }
