"""Trace-driven load generation (DESIGN.md §12).

A *trace* is a JSONL file, one ``SimRequest`` wire object per line — the
committed smoke trace lives at ``examples/traces/smoke.jsonl``.
``replay`` submits a trace against a :class:`ScenarioServer` in *waves*
(each wave re-submits the whole trace under fresh ids, draining between
waves): within a wave same-bucket requests pack into shared batches,
across waves every bucket re-forms and must HIT the compiled-engine
cache — the replay is simultaneously a throughput measurement and a
cache-behaviour check.

The emitted report (``escg-serve-report/v1``) carries request and
lattice-update throughput, the per-request latency profile and the full
serving accounting; ``gate_row`` reshapes it into a ``bench_gate``
family-``serve`` row so serving throughput rides the existing
``--history`` / regression machinery (benchmarks/bench_gate.py — which
imports THIS module; ``repro`` never imports ``benchmarks``).
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Any, Dict, Iterable, List, Sequence, Union

from .protocol import SimRequest, parse_request
from .server import ScenarioServer

__all__ = ["synthetic_trace", "read_trace", "write_trace", "replay",
           "check_report", "gate_row", "REPORT_SCHEMA"]

REPORT_SCHEMA = "escg-serve-report/v1"

# deterministic smoke mix: 3 scenarios x 2 lattice extents over 5
# bucket-distinct combos — any n >= 10 revisits every bucket at least
# twice per wave, so the admission queue actually packs
_COMBOS = (
    ("park3", "batched", (16, 16), 6, 2),
    ("zhong_density", "batched", (16, 16), 6, 1),
    ("nspecies5", "sublattice", (16, 16), 12, 2),
    ("park3", "batched", (32, 16), 12, 1),
    ("zhong_density", "sublattice", (32, 16), 6, 2),
)


def synthetic_trace(n: int = 10, seed: int = 0) -> List[Dict[str, Any]]:
    """``n`` wire-format requests cycling the smoke combo mix with
    distinct seeds (byte-stable for a given ``(n, seed)``)."""
    reqs = []
    for i in range(n):
        scenario, engine, (h, ln), mcs, trials = _COMBOS[i % len(_COMBOS)]
        reqs.append({
            "id": f"r{i + 1}",
            "n_trials": trials,
            "scenario": scenario,
            "engine": {"engine": engine, "tile": [8, 8]},
            "run": {"height": h, "length": ln, "mcs": mcs,
                    "chunk_mcs": 6, "seed": seed + i},
        })
    return reqs


def read_trace(path: str) -> List[Dict[str, Any]]:
    reqs = []
    with open(path, "r", encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if line and not line.startswith("#"):
                reqs.append(json.loads(line))
    return reqs


def write_trace(path: str, reqs: Iterable[Union[dict, SimRequest]]) -> None:
    with open(path, "w", encoding="utf-8") as f:
        for r in reqs:
            wire = r.to_wire() if isinstance(r, SimRequest) else r
            f.write(json.dumps(wire) + "\n")


def replay(server: ScenarioServer,
           requests: Sequence[Union[dict, str, SimRequest]],
           waves: int = 2) -> Dict[str, Any]:
    """Replay ``requests`` through ``server`` ``waves`` times and report.

    Within a wave, all requests are submitted before the drain, so
    same-bucket traffic packs; each later wave re-encounters every
    (bucket, scenario) pair and exercises the cache-hit path."""
    parsed = [parse_request(r) for r in requests]
    ids: List[str] = []
    t0 = time.perf_counter()
    for w in range(max(1, waves)):
        for i, req in enumerate(parsed):
            base = req.id or f"req{i + 1}"
            rid = base if waves <= 1 else f"{base}-w{w + 1}"
            ids.append(server.submit(dataclasses.replace(req, id=rid)))
        server.drain()
    wall_s = time.perf_counter() - t0

    n_ok = n_error = 0
    updates = 0
    for w in range(max(1, waves)):
        for i, req in enumerate(parsed):
            resp = server.response(ids[w * len(parsed) + i])
            if resp is None or not resp.ok:
                n_error += 1
                continue
            n_ok += 1
            res = resp.result
            n_cells = req.run.height * req.run.length
            n_trials = getattr(res, "n_trials", 1)
            updates += int(res.mcs_completed) * n_cells * n_trials

    acct = server.accounting()
    return {
        "schema": REPORT_SCHEMA,
        "n_requests": len(ids),
        "n_ok": n_ok,
        "n_error": n_error,
        "dropped": acct["dropped"],
        "waves": max(1, waves),
        "wall_s": wall_s,
        "requests_per_s": len(ids) / wall_s if wall_s else 0.0,
        "updates": updates,
        "updates_per_s": updates / wall_s if wall_s else 0.0,
        "latency": acct["latency"],
        "cache": acct["cache"],
        "accounting": acct,
    }


def check_report(report: Dict[str, Any]) -> List[str]:
    """Acceptance checks for a replay report; empty list = pass.

    * every admitted request was answered (zero dropped),
    * no request errored,
    * repeat traffic hit the compiled-engine cache at least once."""
    problems = []
    if report.get("schema") != REPORT_SCHEMA:
        problems.append(f"schema {report.get('schema')!r} != "
                        f"{REPORT_SCHEMA!r}")
    if report.get("dropped", -1) != 0:
        problems.append(f"dropped={report.get('dropped')} (want 0)")
    if report.get("n_error", -1) != 0:
        problems.append(f"n_error={report.get('n_error')} (want 0)")
    cache = report.get("cache", {})
    if cache.get("hits", 0) < 1:
        problems.append(f"cache hits={cache.get('hits')} (want >= 1: "
                        "repeated buckets must not re-compile)")
    return problems


def gate_row(report: Dict[str, Any]) -> Dict[str, Any]:
    """A bench_gate family-``serve`` row derived from a replay report
    (appended to BENCH_history.jsonl via the gate's ``--history`` path)."""
    import jax
    rps = report["requests_per_s"]
    mups = report["updates_per_s"] / 1e6
    return {
        "name": "serve_throughput_smoke",
        "family": "serve",
        "scenario": "mixed",
        "local_kernel": "mixed",
        "engine": "server",
        "backend": jax.default_backend(),
        "observables": False,
        "us_per_call": (report["wall_s"] / report["n_requests"] * 1e6
                        if report["n_requests"] else 0.0),
        "derived": f"{rps:.2f} req/s, {mups:.3f} Mupd/s",
        "n_requests": report["n_requests"],
        "requests_per_s": rps,
        "updates_per_s": report["updates_per_s"],
        "cache_hits": report["cache"].get("hits", 0),
        "cache_misses": report["cache"].get("misses", 0),
        "dropped": report["dropped"],
    }
