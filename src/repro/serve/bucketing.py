"""Admission bucketing (DESIGN.md §12): which requests may share a batch.

Two requests can ride one compiled program only when every trace-shaping
knob matches: engine + local kernel + k_mcs select the program, lattice
extent / tile / species / cell dtype / device layout fix its shapes, and
chunk_mcs + the observable set fix the chunk schedule and ring row
layout. Those fields form the :class:`BucketKey`. Physics (dominance
network, action rates, boundary) are baked into the compiled chunk as
constants, so batches additionally group by the scenario content hash
(``scenarios.scenario_key``) — the (bucket, scenario_key) pair IS the
compiled-engine cache key. Seed, MCS budget and trial count are
deliberately excluded: they vary per request within a batch (per-trial
fold-in keys; per-request chunk-boundary accounting in the executor).
"""
from __future__ import annotations

import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, List, NamedTuple, Optional, Tuple

import numpy as np

from ..core.params import EscgParams
from .protocol import SimRequest

__all__ = ["BucketKey", "bucket_key", "Pending", "AdmissionQueue"]


class BucketKey(NamedTuple):
    """Compiled-shape identity of a request (see module docstring)."""
    engine: str
    local_kernel: str
    k_mcs: int
    tile: Tuple[int, int]
    height: int
    length: int
    species: int
    cell_dtype: str
    mesh_shape: Optional[Tuple[int, int, int]]
    shard_grid: Optional[Tuple[int, int]]
    chunk_mcs: int
    observables: Tuple[str, ...]
    obs_capacity: int

    def short(self) -> str:
        """Human-readable form for responses / accounting."""
        return (f"{self.engine}/{self.local_kernel}"
                f"/k{self.k_mcs}/{self.height}x{self.length}"
                f"/S{self.species}/{self.cell_dtype}"
                f"/tile{self.tile[0]}x{self.tile[1]}"
                f"/chunk{self.chunk_mcs}"
                + (f"/obs{len(self.observables)}" if self.observables
                   else ""))


def bucket_key(p: EscgParams) -> BucketKey:
    """The admission bucket of resolved params (post ``resolve_config``,
    so scenario-declared observables are already folded in)."""
    return BucketKey(
        engine=p.engine, local_kernel=p.local_kernel, k_mcs=p.k_mcs,
        tile=tuple(p.tile), height=p.height, length=p.length,
        species=p.species, cell_dtype=p.cell_dtype,
        mesh_shape=(tuple(p.mesh_shape) if p.mesh_shape is not None
                    else None),
        shard_grid=(tuple(p.shard_grid) if p.shard_grid is not None
                    else None),
        chunk_mcs=p.chunk_mcs, observables=tuple(p.observables),
        obs_capacity=p.obs_capacity)


@dataclass
class Pending:
    """One admitted request waiting in its bucket group."""
    seq: int
    req: SimRequest
    params: EscgParams             # resolved + validated
    dom: np.ndarray
    bucket: BucketKey
    scenario_key: str
    kind: str                      # 'pod' | 'vmap' | 'single'
    n_mcs: int
    # strict-schedule token: normally None (any same-bucket MCS budgets
    # pack — trajectories and per-MCS stats are chunk-schedule invariant);
    # set to the MCS budget when k_mcs > 1 streams grid-derived (lag-held)
    # observables, whose rows DO depend on launch-group boundaries — only
    # identical schedules may then share a batch (DESIGN.md §12)
    sched: Optional[int] = None
    t_submit: float = field(default_factory=time.perf_counter)

    @property
    def group(self) -> Tuple[BucketKey, str, Optional[int]]:
        return (self.bucket, self.scenario_key, self.sched)


class AdmissionQueue:
    """FIFO-of-groups queue: requests group by (bucket, scenario_key);
    the drain policy (``pop_batch``) picks by age unless a group has
    accumulated a full batch, in which case occupancy wins — the same
    age/occupancy rule continuous-batching LM servers use."""

    def __init__(self) -> None:
        self._groups: "OrderedDict[Tuple, List[Pending]]" = OrderedDict()
        self._n_pending = 0

    def __len__(self) -> int:
        return self._n_pending

    def push(self, pending: Pending) -> None:
        self._groups.setdefault(pending.group, []).append(pending)
        self._n_pending += 1

    def depth(self) -> Dict[str, int]:
        """Trials queued per group (accounting surface). The key carries
        the FULL group identity — bucket, full scenario hash and the
        strict-schedule token — so two live groups can never collapse
        into (and overwrite) one reported entry."""
        out: Dict[str, int] = {}
        for (b, sk, sched), plist in self._groups.items():
            key = f"{b.short()}@{sk}"
            if sched is not None:
                key += f"/sched{sched}"
            out[key] = self._trials(plist)
        return out

    def _trials(self, plist: List[Pending]) -> int:
        return sum(max(1, p.req.n_trials) for p in plist)

    def pop_batch(self, max_batch_trials: int
                  ) -> Optional[Tuple[Tuple, List[Pending]]]:
        """The next batch to run: all of one group up to
        ``max_batch_trials`` trials (always at least one request).

        Policy: any group holding >= max_batch_trials trials is drained
        first (occupancy — a full pod beats fairness); otherwise the
        group containing the OLDEST pending request runs (age — no
        request starves behind a popular bucket)."""
        if not self._groups:
            return None
        full = [g for g, plist in self._groups.items()
                if self._trials(plist) >= max_batch_trials]
        if full:
            gkey = max(full, key=lambda g: self._trials(self._groups[g]))
        else:
            gkey = min(self._groups,
                       key=lambda g: self._groups[g][0].seq)
        plist = self._groups[gkey]
        take, trials = [], 0
        while plist and (not take
                         or trials + max(1, plist[0].req.n_trials)
                         <= max_batch_trials):
            p = plist.pop(0)
            take.append(p)
            trials += max(1, p.req.n_trials)
        if not plist:
            del self._groups[gkey]
        self._n_pending -= len(take)
        return gkey, take
