"""SimRequest / SimResponse — the serving wire protocol (DESIGN.md §12).

A request is the scenario-first driver call, reified: WHAT to simulate
(a :class:`~repro.core.scenarios.Scenario` or a registered preset name),
HOW (an :class:`~repro.core.scenarios.EngineConfig`), HOW LONG
(a :class:`~repro.core.scenarios.RunConfig`) and how many IID trials.
The server promises bit-identity: the response's result equals a direct
``run_trials(scenario, n_trials, engine=..., run=...)`` (or, for the
non-vmappable single-lattice engines, ``simulate(scenario, ...)``) call
with the same configs — whatever other traffic shared the batch.

Wire format: one JSON object per request —

``{"id": "r1", "n_trials": 2, "scenario": "park3" | {...Scenario...},
"engine": {...partial EngineConfig...}, "run": {...partial RunConfig...}}``

Partial engine/run objects carry only the overridden fields; a bare
scenario name resolves through the scenario registry (parametric
suffixes included, e.g. ``"nspecies7"``). Responses serialize the
result through the unified ``RunResult`` JSON surface
(``core/results.py``), tagged with ``kind`` so the client knows whether
to rebuild a ``TrialResult`` (``"trials"``) or ``SimResult``
(``"single"``).
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Union

from ..core.scenarios import (EngineConfig, RunConfig, Scenario,
                              make_scenario)
from ..core.simulation import SimResult
from ..core.trials import TrialResult

__all__ = [
    "SimRequest", "SimResponse", "scenario_from_wire",
    "engine_config_from_wire", "run_config_from_wire", "parse_request",
]


def scenario_from_wire(obj: Union[str, Dict[str, Any], Scenario]
                       ) -> Scenario:
    """A wire scenario — preset name, full/partial field object (an
    optional ``"name"`` routes through the registry builder so preset
    coupling like Park's mobility→epsilon rule is preserved), or an
    already-built ``Scenario``."""
    if isinstance(obj, Scenario):
        return obj.validate()
    if isinstance(obj, str):
        return make_scenario(obj)
    if not isinstance(obj, dict):
        raise ValueError(f"scenario must be a name or object, got "
                         f"{type(obj).__name__}")
    d = dict(obj)
    fields = {f.name for f in dataclasses.fields(Scenario)}
    unknown = set(d) - fields
    if unknown:
        raise ValueError(f"unknown Scenario fields {sorted(unknown)}; "
                         f"accepted: {sorted(fields)}")
    return Scenario(**d).validate()


def _tupled(d: Dict[str, Any], *keys: str) -> Dict[str, Any]:
    for k in keys:
        if d.get(k) is not None:
            d[k] = tuple(d[k])
    return d


def engine_config_from_wire(obj: Optional[Dict[str, Any]]) -> EngineConfig:
    if obj is None:
        return EngineConfig()
    if isinstance(obj, EngineConfig):
        return obj
    d = _tupled(dict(obj), "tile", "shard_grid", "mesh_shape")
    fields = {f.name for f in dataclasses.fields(EngineConfig)}
    unknown = set(d) - fields
    if unknown:
        raise ValueError(f"unknown EngineConfig fields {sorted(unknown)}")
    return EngineConfig(**d)


def run_config_from_wire(obj: Optional[Dict[str, Any]]) -> RunConfig:
    if obj is None:
        return RunConfig()
    if isinstance(obj, RunConfig):
        return obj
    d = _tupled(dict(obj), "observables")
    fields = {f.name for f in dataclasses.fields(RunConfig)}
    unknown = set(d) - fields
    if unknown:
        raise ValueError(f"unknown RunConfig fields {sorted(unknown)}")
    return RunConfig(**d)


@dataclass(frozen=True)
class SimRequest:
    """One serving request: scenario + engine + run + trial count.

    The constructor accepts the same shapes as the wire format — a preset
    name / field dict for ``scenario`` and partial dicts for
    ``engine`` / ``run`` — and normalizes them to the frozen config
    dataclasses, so in-process callers need no separate parse step."""
    scenario: Scenario
    engine: EngineConfig = field(default_factory=EngineConfig)
    run: RunConfig = field(default_factory=RunConfig)
    n_trials: int = 1
    id: str = ""

    def __post_init__(self):
        object.__setattr__(self, "scenario",
                           scenario_from_wire(self.scenario))
        object.__setattr__(self, "engine",
                           engine_config_from_wire(self.engine))
        object.__setattr__(self, "run", run_config_from_wire(self.run))

    def to_wire(self) -> Dict[str, Any]:
        return {
            "id": self.id,
            "n_trials": self.n_trials,
            "scenario": dataclasses.asdict(self.scenario),
            "engine": dataclasses.asdict(self.engine),
            "run": dataclasses.asdict(self.run),
        }

    def to_json(self) -> str:
        return json.dumps(self.to_wire())

    @staticmethod
    def from_wire(obj: Dict[str, Any]) -> "SimRequest":
        if not isinstance(obj, dict):
            raise ValueError("request must be a JSON object")
        if "scenario" not in obj:
            raise ValueError("request missing 'scenario'")
        return SimRequest(
            scenario=scenario_from_wire(obj["scenario"]),
            engine=engine_config_from_wire(obj.get("engine")),
            run=run_config_from_wire(obj.get("run")),
            n_trials=int(obj.get("n_trials", 1)),
            id=str(obj.get("id", "")),
        )

    @staticmethod
    def from_json(s: str) -> "SimRequest":
        return SimRequest.from_wire(json.loads(s))


def parse_request(obj: Union[str, Dict[str, Any], "SimRequest"]
                  ) -> "SimRequest":
    """Normalize any accepted submit payload to a ``SimRequest``."""
    if isinstance(obj, SimRequest):
        return obj
    if isinstance(obj, str):
        return SimRequest.from_json(obj)
    return SimRequest.from_wire(obj)


@dataclass
class SimResponse:
    """The server's answer for one request.

    ``kind`` selects the result type: ``"trials"`` (a ``TrialResult``
    from the packed pod-axis path), ``"single"`` (a ``SimResult`` from
    the single-lattice path for non-vmappable engines) or ``"error"``
    (``result`` is None and ``error`` carries the admission/runtime
    message). ``timing`` records per-request latency in seconds:
    ``queue_s`` (submit → batch start), ``compile_s`` (engine-cache
    build time, 0.0 on a cache hit) and ``run_s`` (the batch execution
    this request rode). ``cache_hit`` / ``bucket`` / ``scenario_key``
    expose the scheduling identity for accounting and tests."""
    id: str
    ok: bool
    kind: str                      # 'trials' | 'single' | 'error'
    result: Optional[object] = None   # TrialResult | SimResult | None
    error: str = ""
    timing: Dict[str, float] = field(default_factory=dict)
    cache_hit: bool = False
    bucket: str = ""
    scenario_key: str = ""

    def to_wire(self) -> Dict[str, Any]:
        return {
            "id": self.id,
            "ok": self.ok,
            "kind": self.kind,
            "result": (json.loads(self.result.to_json())
                       if self.result is not None else None),
            "error": self.error,
            "timing": self.timing,
            "cache_hit": self.cache_hit,
            "bucket": self.bucket,
            "scenario_key": self.scenario_key,
        }

    def to_json(self) -> str:
        return json.dumps(self.to_wire())

    @staticmethod
    def from_wire(obj: Dict[str, Any]) -> "SimResponse":
        result = None
        if obj.get("result") is not None:
            payload = json.dumps(obj["result"])
            result = (TrialResult.from_json(payload)
                      if obj.get("kind") == "trials"
                      else SimResult.from_json(payload))
        return SimResponse(
            id=str(obj.get("id", "")), ok=bool(obj.get("ok")),
            kind=str(obj.get("kind", "error")), result=result,
            error=str(obj.get("error", "")),
            timing=dict(obj.get("timing", {})),
            cache_hit=bool(obj.get("cache_hit")),
            bucket=str(obj.get("bucket", "")),
            scenario_key=str(obj.get("scenario_key", "")),
        )

    @staticmethod
    def from_json(s: str) -> "SimResponse":
        return SimResponse.from_wire(json.loads(s))
