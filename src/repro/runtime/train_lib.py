"""[LM-scaffold appendix — DESIGN.md §9.] Train step builders shared by
the quarantined LM launcher (``repro.launch.train``) and dry-run; no
ESCG module imports this."""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.registry import Model
from ..models.spec import ParamSpec
from ..optim import clip_by_global_norm, get_optimizer


def state_specs(model: Model, compress: bool = False) -> Dict[str, Any]:
    """ParamSpec tree for the full train state (params + opt + step
    [+ error-feedback residuals when gradient compression is on])."""
    opt = get_optimizer(model.cfg.optimizer)
    specs = {
        "params": model.param_specs,
        "opt": opt.state_specs(model.param_specs),
        "step": ParamSpec((), (), init="zeros", dtype="int32"),
    }
    if compress:
        from ..optim import compression
        specs["ef"] = compression.ef_state_specs(model.param_specs)
    return specs


def make_train_step(model: Model,
                    schedule: Optional[Callable] = None,
                    grad_clip: float = 1.0,
                    compress: bool = False) -> Callable:
    """(state, batch) -> (state, metrics). Pure; jit/pjit it yourself.

    ``compress``: int8 error-feedback gradient compression — the residual
    buffer lives IN the train state (it must persist across jitted steps).
    """
    opt = get_optimizer(model.cfg.optimizer)
    if schedule is None:
        schedule = lambda step: jnp.float32(3e-4)       # noqa: E731

    def train_step(state, batch):
        (loss, mets), grads = jax.value_and_grad(
            model.loss, has_aux=True)(state["params"], batch)
        new_ef = None
        if compress:
            from ..optim import compression
            grads, new_ef = compression.compress_grads(grads, state["ef"])
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        lr = schedule(state["step"])
        params, opt_state = opt.apply(state["params"], grads, state["opt"],
                                      lr, state["step"])
        new_state = {"params": params, "opt": opt_state,
                     "step": state["step"] + 1}
        if compress:
            new_state["ef"] = new_ef
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr, **mets}
        return new_state, metrics

    return train_step


def make_prefill_step(model: Model, max_len: int) -> Callable:
    def prefill_step(params, batch):
        return model.prefill(params, batch, max_len=max_len)
    return prefill_step


def make_decode_step(model: Model) -> Callable:
    def decode_step(params, cache, batch):
        return model.decode_step(params, cache, batch["tokens"])
    return decode_step


def init_state(model: Model, key: jax.Array,
               compress: bool = False) -> Dict[str, Any]:
    from ..models import spec as spec_mod
    specs = state_specs(model, compress)
    state = {k: spec_mod.initialize(v, key) if k != "params" else
             model.init(key) for k, v in specs.items()}
    state["step"] = jnp.int32(0)
    return state


def abstract_state(model: Model, compress: bool = False) -> Dict[str, Any]:
    from ..models import spec as spec_mod
    return spec_mod.abstract(state_specs(model, compress))
