from . import checkpoint, fault, train_lib
