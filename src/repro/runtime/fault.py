"""Fault tolerance: checkpoint/restart loop, straggler monitor, elastic
remesh (DESIGN.md §9).

The paper's --resume flag is the single-process version of this; here the
same manifest-driven checkpoints back a restart-on-failure training loop and
an elastic path that reshards any checkpoint onto a different mesh.
"""
from __future__ import annotations

import logging
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from .checkpoint import CheckpointManager

log = logging.getLogger("repro.runtime")


@dataclass
class StragglerMonitor:
    """Flags steps whose wall time exceeds k x running median — on real
    fleets this triggers node replacement; here it logs and counts."""
    k: float = 3.0
    window: int = 50
    times: List[float] = field(default_factory=list)
    flagged: int = 0

    def record(self, dt: float) -> bool:
        self.times.append(dt)
        hist = self.times[-self.window:]
        if len(hist) >= 10:
            med = float(np.median(hist[:-1]))
            if dt > self.k * med:
                self.flagged += 1
                log.warning("straggler step: %.3fs > %.1fx median %.3fs",
                            dt, self.k, med)
                return True
        return False


@dataclass
class Heartbeat:
    """Liveness marker a fleet supervisor would watch."""
    path: str
    interval_s: float = 30.0
    _last: float = 0.0

    def beat(self, step: int) -> None:
        now = time.time()
        if now - self._last >= self.interval_s:
            with open(self.path, "w") as f:
                f.write(f"{step} {now}\n")
            self._last = now


class FaultTolerantLoop:
    """Run (state, batch) -> (state, metrics) with periodic checkpoints and
    restart-from-latest on failure.

    ``max_restarts`` bounds crash loops; ``inject_failure`` lets tests
    exercise the restart path deterministically.
    """

    def __init__(self, step_fn: Callable, ckpt: CheckpointManager,
                 ckpt_every: int = 100, max_restarts: int = 3,
                 straggler: Optional[StragglerMonitor] = None,
                 heartbeat: Optional[Heartbeat] = None):
        self.step_fn = step_fn
        self.ckpt = ckpt
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts
        self.straggler = straggler or StragglerMonitor()
        self.heartbeat = heartbeat
        self.restarts = 0

    def run(self, state: Any, batches: Callable[[int], Any], n_steps: int,
            start_step: int = 0,
            inject_failure: Optional[Callable[[int], bool]] = None,
            shardings: Any = None,
            on_metrics: Optional[Callable[[int, Dict], None]] = None):
        step = start_step
        while step < n_steps:
            try:
                t0 = time.time()
                if inject_failure is not None and inject_failure(step):
                    raise RuntimeError(f"injected failure at step {step}")
                state, metrics = self.step_fn(state, batches(step))
                dt = time.time() - t0
                self.straggler.record(dt)
                if self.heartbeat:
                    self.heartbeat.beat(step)
                if on_metrics:
                    on_metrics(step, metrics)
                step += 1
                if step % self.ckpt_every == 0 or step == n_steps:
                    self.ckpt.save(step, state, blocking=False)
            except Exception as e:                      # noqa: BLE001
                self.restarts += 1
                log.error("step %d failed (%s); restart %d/%d", step, e,
                          self.restarts, self.max_restarts)
                if self.restarts > self.max_restarts:
                    raise
                self.ckpt.wait()
                latest = self.ckpt.latest_step()
                if latest is None:
                    step = start_step     # no checkpoint yet: retry from go
                    continue
                step, state = self.ckpt.restore(latest, shardings=shardings)
        self.ckpt.wait()
        return state, step


def elastic_restore(ckpt: CheckpointManager, new_shardings: Any,
                    step: Optional[int] = None):
    """Resume on a DIFFERENT mesh: the checkpoint's global arrays are
    resharded onto `new_shardings` (restore is sharding-agnostic)."""
    return ckpt.restore(step, shardings=new_shardings)
