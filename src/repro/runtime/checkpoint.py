"""Sharded checkpointing: save/restore arbitrary pytrees of (possibly
distributed) arrays with a manifest + per-leaf .npy payloads.

Design (1000+-node posture, DESIGN.md §9):
  * every leaf is written per-addressable-shard with its global index
    bounds, so each HOST writes only its local shards (no gather);
  * restore is sharding-agnostic: any mesh/sharding can load any checkpoint
    (the elastic-remesh path) — each device reads the slices overlapping
    its assigned shard;
  * atomic publish: write to ``step_XXXX.tmp`` then ``os.replace`` the
    directory marker; a crash mid-write never corrupts the latest link;
  * retention: keep the newest K checkpoints;
  * async: ``save(..., blocking=False)`` hands the host copy to a writer
    thread (double-buffered — at most one outstanding save).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

MANIFEST = "manifest.json"
_MARKER = "COMMITTED"


def _leaf_paths(tree, prefix="") -> List[Tuple[str, Any]]:
    if isinstance(tree, dict):
        out = []
        for k in sorted(tree.keys()):
            out.extend(_leaf_paths(tree[k], f"{prefix}/{k}" if prefix
                                   else k))
        return out
    return [(prefix, tree)]


def _unflatten(items: Dict[str, Any]) -> Any:
    root: Dict[str, Any] = {}
    for path, v in items.items():
        parts = path.split("/")
        d = root
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = v
    return root


def _slug(path: str) -> str:
    return path.replace("/", ".")


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------ save ------------------------------- #
    def save(self, step: int, tree: Any, blocking: bool = True) -> str:
        """Snapshot `tree` at `step`. Device->host copy happens here;
        file IO happens inline (blocking) or on the writer thread."""
        self.wait()
        leaves = _leaf_paths(tree)
        host_data = []
        manifest: Dict[str, Any] = {"step": int(step), "leaves": {}}
        for path, arr in leaves:
            if isinstance(arr, jax.Array) and hasattr(arr, "addressable_shards"):
                shards = []
                for sh in arr.addressable_shards:
                    idx = sh.index
                    bounds = [[(s.start or 0),
                               (s.stop if s.stop is not None else dim)]
                              for s, dim in zip(idx, arr.shape)] \
                        if idx != () else []
                    shards.append((bounds, np.asarray(sh.data)))
                manifest["leaves"][path] = {
                    "shape": list(arr.shape), "dtype": str(arr.dtype),
                    "n_shards": len(shards)}
                host_data.append((path, shards))
            else:
                a = np.asarray(arr)
                manifest["leaves"][path] = {
                    "shape": list(a.shape), "dtype": str(a.dtype),
                    "n_shards": 1}
                host_data.append((path, [([], a)]))

        final = os.path.join(self.dir, f"step_{int(step):010d}")

        def write():
            tmp = final + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            for path, shards in host_data:
                seen = set()
                for i, (bounds, data) in enumerate(shards):
                    key = json.dumps(bounds)
                    if key in seen:            # replicated shards: write once
                        continue
                    seen.add(key)
                    np.save(os.path.join(tmp, f"{_slug(path)}.{i}.npy"),
                            data)
                    manifest["leaves"][path].setdefault("bounds", {})[
                        str(i)] = bounds
            with open(os.path.join(tmp, MANIFEST), "w") as f:
                json.dump(manifest, f)
            with open(os.path.join(tmp, _MARKER), "w") as f:
                f.write("ok")
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        return final

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    # ----------------------------- restore ----------------------------- #
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp") and \
                    os.path.exists(os.path.join(self.dir, name, _MARKER)):
                out.append(int(name[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None,
                shardings: Optional[Any] = None) -> Tuple[int, Any]:
        """Load a checkpoint. ``shardings``: optional pytree of
        NamedSharding with the SAME structure — leaves are placed (and
        resharded if the mesh changed: elastic restart)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{int(step):010d}")
        with open(os.path.join(d, MANIFEST)) as f:
            manifest = json.load(f)

        shard_map_tree = (_leaf_paths(shardings)
                          if shardings is not None else None)
        shard_lookup = dict(shard_map_tree) if shard_map_tree else {}

        items: Dict[str, Any] = {}
        for path, meta in manifest["leaves"].items():
            full = np.zeros(meta["shape"], dtype=np.dtype(meta["dtype"]))
            bounds_map = meta.get("bounds", {})
            for i in range(meta["n_shards"]):
                fn = os.path.join(d, f"{_slug(path)}.{i}.npy")
                if not os.path.exists(fn):
                    continue
                data = np.load(fn)
                b = bounds_map.get(str(i), [])
                if b:
                    sl = tuple(slice(lo, hi) for lo, hi in b)
                    full[sl] = data
                else:
                    full[...] = data
            sh = shard_lookup.get(path)
            if sh is not None:
                items[path] = jax.device_put(full, sh)
            else:
                items[path] = jax.numpy.asarray(full)
        return int(manifest["step"]), _unflatten(items)
